"""Fault-tolerant checkpointing with elastic restore.

Design (DESIGN.md §3 'Fault tolerance'):
  - per-leaf .npy blobs under step directories, written tmp-then-rename;
  - a manifest.json committed LAST by atomic rename: a checkpoint is
    visible iff its manifest exists, so a crash mid-save can never be
    mistaken for a complete checkpoint (same commit protocol as the
    cold tier's delta log);
  - SHA-256 content checksums per leaf, verified on load;
  - ELASTIC restore: leaves are saved as FULL logical arrays (gathered
    from the mesh), so a checkpoint written on a 256-chip mesh restores
    onto 512 chips, 8 chips, or 1 CPU — resharding happens at load via
    jax.device_put with the target sharding;
  - async save: the gather runs inline (cheap vs training step) and the
    disk write happens on a background thread, overlapping the next step;
  - retention: keep_last N checkpoints are retained, older ones pruned.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from ..core.hashing import blob_checksum


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[dict] = None) -> str:
        """Gather shards to host, then write (optionally async)."""
        host_leaves = [(name, np.asarray(leaf))
                       for name, leaf in _flatten(tree)]
        if blocking:
            self._write(step, host_leaves, extra or {})
        else:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_leaves, extra or {}))
            self._pending.start()
        return self._step_dir(step)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def _write(self, step: int, leaves, extra: dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "extra": extra}
        for i, (name, arr) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            path = os.path.join(tmp, fname)
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            with open(path, "rb") as f:
                csum = blob_checksum(f.read())
            manifest["leaves"][name] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": csum}
        # manifest written INSIDE tmp, then the whole dir renamed: the
        # rename is the commit point
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- load ---------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, d,
                                                "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True
                ) -> tuple[Any, int, dict]:
        """Restore into the STRUCTURE of target_tree (shapes must match;
        device layout need not — elastic remesh via `shardings`, a pytree
        of NamedSharding or None for host arrays)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        names = [name for name, _ in _flatten(target_tree)]
        missing = [n for n in names if n not in manifest["leaves"]]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}")

        flat, treedef = jax.tree_util.tree_flatten(target_tree)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat))
        new_leaves = []
        for name, tgt, shd in zip(names, flat, shard_flat):
            meta = manifest["leaves"][name]
            path = os.path.join(d, meta["file"])
            if verify:
                with open(path, "rb") as f:
                    if blob_checksum(f.read()) != meta["sha256"]:
                        raise IOError(f"checksum mismatch for {name}")
            arr = np.load(path)
            if list(arr.shape) != list(tgt.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {tgt.shape}")
            if shd is not None:
                arr = jax.device_put(arr, shd)    # elastic reshard
            new_leaves.append(arr)
        return treedef.unflatten(new_leaves), step, manifest.get("extra", {})
