"""Gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound DP all-reduce).

int8 uniform quantization per tensor with an error-feedback accumulator
(Seide et al. / EF-SGD): the quantization residual is carried into the
next step, so compression bias vanishes asymptotically. Compressing
BEFORE the data-parallel all-reduce cuts DP collective bytes 4x (fp32) /
2x (bf16); the roofline collective term scales accordingly.

Usage: wrap the grad function —
    grads, cstate = compress_decompress(grads, cstate)
(in a real pod the all-reduce happens between compress and decompress;
under pjit the XLA partitioner owns the all-reduce, so we apply
quantize+dequantize around it — the *bytes on the wire* story is encoded
in the sharding annotations; see launch/sharding.py.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(params):
    """Error-feedback accumulators, one per leaf."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, ef_state):
    """Apply int8 quantize->dequantize with error feedback.
    Returns (decompressed grads, new ef_state)."""

    def per_leaf(g, e):
        g32 = g.astype(jnp.float32) + e          # add carried error
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq    # new error

    out = jax.tree.map(per_leaf, grads, ef_state)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
