"""Sharded optimizers (pure JAX, optax-free).

Two tiers, chosen per architecture by memory napkin math (DESIGN.md §6):

  - AdamW: dense LMs (12-32B params). fp32 m/v states; with ZeRO-1 the
    states shard over BOTH mesh axes (launch/sharding.py), so the per-chip
    footprint is params_bf16/TP + 8 bytes/param / (DP*TP).

  - Adafactor: the 1T-param MoE (kimi-k2). Factored second moment — row
    and column accumulators instead of a full (d_in, d_out) tensor —
    ~2 bytes/param total state. This is what makes a 1T model fit 16GB
    chips at 512-way sharding.

Both expose the same (init, update) pair over arbitrary pytrees.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_state)


def _layer_mapped(fn, out_ndim_hint=None):
    """Stream an elementwise per-leaf update over the leading (layer)
    dim with lax.map when the leaf is layer-stacked (ndim >= 3): the fp32
    working copies then cost 1/L of the leaf instead of materializing a
    full f32 cast of, e.g., a 5 GB expert-weight shard (EXPERIMENTS.md
    §Perf G7)."""

    def wrapped(*arrays):
        if arrays[0].ndim >= 3 and arrays[0].shape[0] > 1:
            def body(xs):
                # optimization_barrier stops XLA from hoisting the
                # per-slice f32 converts OUT of the loop (which would
                # materialize full f32 stacks and defeat the streaming)
                return fn(*jax.lax.optimization_barrier(xs))
            return jax.lax.map(body, arrays)
        return fn(*arrays)

    return wrapped


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          warmup_steps: int = 100) -> Optimizer:
    def schedule(step):
        warm = jnp.minimum(1.0, (step + 1) / warmup_steps)
        return lr * warm

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr_t = schedule(step)
        bc1 = 1.0 - b1 ** (step + 1.0)
        bc2 = 1.0 - b2 ** (step + 1.0)

        def upd_leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + \
                weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
                m_new, v_new

        upd = _layer_mapped(upd_leaf)
        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, factored second moment)
# ---------------------------------------------------------------------------
def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              warmup_steps: int = 100) -> Optimizer:
    """Factored state for >=2D params (row/col accumulators over the two
    trailing dims); full state for 0/1D. bf16-param friendly: no fp32
    master copy, no momentum."""

    def schedule(step):
        warm = jnp.minimum(1.0, (step + 1) / warmup_steps)
        return lr * warm

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p):
                row_shape = p.shape[:-1]           # reduce over last dim
                col_shape = p.shape[:-2] + p.shape[-1:]
                return {"r": jnp.zeros(row_shape, jnp.float32),
                        "c": jnp.zeros(col_shape, jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_leaf, params)

    def update(grads, state, params, step):
        lr_t = schedule(step)
        beta = 1.0 - (step + 1.0) ** -decay        # increasing decay

        def clip_apply(u, p):
            # update clipping (RMS(u) <= clip_threshold) — applied per
            # layer slice under lax.map = per logical parameter matrix
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        def upd_factored(g, r_s, c_s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            r = beta * r_s + (1 - beta) * g2.mean(axis=-1)
            c = beta * c_s + (1 - beta) * g2.mean(axis=-2)
            r_norm = r / jnp.maximum(r.mean(axis=-1, keepdims=True), eps)
            v_inv = jax.lax.rsqrt(
                jnp.maximum(r_norm[..., None] * c[..., None, :], eps))
            return clip_apply(g * v_inv, p), r, c

        def upd_full(g, v_s, p):
            g = g.astype(jnp.float32)
            v = beta * v_s + (1 - beta) * (jnp.square(g) + eps)
            return clip_apply(g * jax.lax.rsqrt(jnp.maximum(v, eps)),
                              p), v

        flat_p, tree = jax.tree_util.tree_flatten(params)
        flat_g = tree.flatten_up_to(grads)
        flat_s = tree.flatten_up_to(state)
        new = []
        for g, s, p in zip(flat_g, flat_s, flat_p):
            if _factored(p):
                np_, r, c = _layer_mapped(upd_factored)(g, s["r"], s["c"],
                                                        p)
                new.append((np_, {"r": r, "c": c}))
            else:
                np_, v = _layer_mapped(upd_full)(g, s["v"], p)
                new.append((np_, {"v": v}))
        new_params = tree.unflatten([n[0] for n in new])
        new_state = tree.unflatten([n[1] for n in new])
        return new_params, new_state

    return Optimizer(init, update)


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    if name == "sgd":
        return sgd(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
