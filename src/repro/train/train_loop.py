"""Generic fault-tolerant training loop.

Composes: model loss fn + sharded optimizer + checkpoint manager +
optional gradient compression. The jitted step function is exactly what
the multi-pod dry-run lowers (launch/dryrun.py), so the loop that runs on
one CPU in tests is the same object that shards across 512 chips.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import grad_compress
from .checkpoint import CheckpointManager
from .optimizer import Optimizer


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0
    ef_state: Any = None          # error-feedback accumulators (optional)


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    compress: bool = False) -> Callable:
    """loss_fn(params, batch) -> scalar. Returns jit-able
    step(params, opt_state, ef_state, batch, step) ->
    (params, opt_state, ef_state, metrics)."""

    def step_fn(params, opt_state, ef_state, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            grads, ef_state = grad_compress.compress_decompress(
                grads, ef_state)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               step)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return new_params, new_opt, ef_state, {"loss": loss,
                                               "grad_norm": gnorm}

    return step_fn


class Trainer:
    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 params: Any, checkpoint_dir: Optional[str] = None,
                 compress_grads: bool = False,
                 checkpoint_every: int = 100, keep_last: int = 3,
                 async_checkpoint: bool = True):
        self.optimizer = optimizer
        self.state = TrainState(
            params=params, opt_state=optimizer.init(params),
            ef_state=(grad_compress.init_state(params)
                      if compress_grads else None))
        self.compress = compress_grads
        self.step_fn = jax.jit(make_train_step(loss_fn, optimizer,
                                               compress_grads))
        self.ckpt = (CheckpointManager(checkpoint_dir, keep_last)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.async_checkpoint = async_checkpoint
        self.history: list[dict] = []

    # -- restart-resume -------------------------------------------------
    def try_restore(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        tree = {"params": self.state.params,
                "opt_state": self.state.opt_state}
        restored, step, _ = self.ckpt.restore(tree)
        self.state.params = restored["params"]
        self.state.opt_state = restored["opt_state"]
        self.state.step = step
        return True

    def run(self, batches, n_steps: Optional[int] = None,
            log_every: int = 10) -> list[dict]:
        t0 = time.perf_counter()
        for i, batch in enumerate(batches):
            if n_steps is not None and i >= n_steps:
                break
            s = self.state
            new_p, new_o, new_e, metrics = self.step_fn(
                s.params, s.opt_state, s.ef_state, batch,
                jnp.asarray(s.step, jnp.int32))
            s.params, s.opt_state, s.ef_state = new_p, new_o, new_e
            s.step += 1
            if s.step % log_every == 0 or i == 0:
                rec = {"step": s.step,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "wall_s": time.perf_counter() - t0}
                self.history.append(rec)
            if self.ckpt and s.step % self.checkpoint_every == 0:
                self.checkpoint()
        if self.ckpt:
            self.ckpt.wait()
        return self.history

    def checkpoint(self) -> None:
        assert self.ckpt is not None
        self.ckpt.save(self.state.step,
                       {"params": self.state.params,
                        "opt_state": self.state.opt_state},
                       blocking=not self.async_checkpoint)
