"""CDC classification tests (paper §III-A3 + §V-B3 ground-truth check)."""
from repro.core.cdc import detect_changes, positional_diff
from repro.core.chunking import chunk_document


def _hashes(text):
    return [c.chunk_id for c in chunk_document(text)]


DOC_V1 = "Alpha paragraph.\n\nBeta paragraph.\n\nGamma paragraph."


class TestDetectChanges:
    def test_initial_ingest_all_new(self):
        cs = detect_changes(chunk_document(DOC_V1), [])
        assert len(cs.new) == 3
        assert cs.n_changed == 3 and cs.reprocess_fraction == 1.0

    def test_no_change(self):
        cs = detect_changes(chunk_document(DOC_V1), _hashes(DOC_V1))
        assert len(cs.unchanged) == 3
        assert cs.n_changed == 0 and cs.reprocess_fraction == 0.0

    def test_single_modification(self):
        v2 = "Alpha paragraph.\n\nBeta paragraph EDITED.\n\nGamma paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert len(cs.modified) == 1 and cs.modified[0].position == 1
        assert len(cs.unchanged) == 2
        assert not cs.deleted and not cs.new
        assert abs(cs.reprocess_fraction - 1 / 3) < 1e-9

    def test_append_is_new(self):
        v2 = DOC_V1 + "\n\nDelta paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert len(cs.new) == 1 and cs.new[0].position == 3
        assert len(cs.unchanged) == 3 and not cs.deleted

    def test_truncation_is_deleted(self):
        v2 = "Alpha paragraph.\n\nBeta paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert len(cs.deleted) == 1
        assert cs.deleted[0][0] == 2          # gamma's old position

    def test_modification_not_double_counted_as_delete(self):
        v2 = "Alpha paragraph.\n\nBeta paragraph EDITED.\n\nGamma paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert not cs.deleted                  # superseded, NOT deleted

    def test_move_needs_no_reembedding(self):
        v2 = "Beta paragraph.\n\nAlpha paragraph.\n\nGamma paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert len(cs.moved) == 2 and len(cs.unchanged) == 1
        assert cs.n_changed == 0               # zero embedding work

    def test_front_deletion_detected_as_single_delete(self):
        v2 = "Beta paragraph.\n\nGamma paragraph."
        cs = detect_changes(chunk_document(v2), _hashes(DOC_V1))
        assert len(cs.moved) == 2
        assert len(cs.deleted) == 1            # alpha gone
        assert cs.n_changed == 0

    def test_duplicate_content_occurrences(self):
        v1 = "Same.\n\nSame.\n\nOther."
        v2 = "Same.\n\nOther."
        cs = detect_changes(chunk_document(v2), _hashes(v1))
        # one 'Same' occurrence deleted, one kept
        assert len(cs.deleted) == 1

    def test_100_percent_detection_accuracy(self):
        """Paper §V-B3: 147/147 TP, 0 FP, 0 FN on ground-truth edits."""
        import random
        rng = random.Random(7)
        words = ["alpha", "beta", "gamma", "delta", "eps", "zeta", "eta"]
        tp = fp = fn = 0
        for trial in range(50):
            paras = [" ".join(rng.choices(words, k=12)) + f" p{i}"
                     for i in range(10)]
            v1 = "\n\n".join(paras)
            edit_pos = rng.randrange(10)
            paras2 = list(paras)
            paras2[edit_pos] = paras2[edit_pos] + " EDITED"
            v2 = "\n\n".join(paras2)
            cs = detect_changes(chunk_document(v2), _hashes(v1))
            detected = {c.position for c in cs.modified}
            tp += int(edit_pos in detected)
            fp += len(detected - {edit_pos}) + len(cs.new) + len(cs.deleted)
            fn += int(edit_pos not in detected)
        assert (tp, fp, fn) == (50, 0, 0)


class TestPositionalDiff:
    def test_modify(self):
        v2 = "Alpha paragraph.\n\nBeta EDITED.\n\nGamma paragraph."
        close, append = positional_diff(chunk_document(v2), _hashes(DOC_V1))
        assert close == [1] and append == [1]

    def test_append_and_truncate(self):
        close, append = positional_diff(chunk_document(DOC_V1 + "\n\nD."),
                                        _hashes(DOC_V1))
        assert close == [] and append == [3]
        close, append = positional_diff(
            chunk_document("Alpha paragraph."), _hashes(DOC_V1))
        assert close == [1, 2] and append == []

    def test_initial(self):
        close, append = positional_diff(chunk_document(DOC_V1), [])
        assert close == [] and append == [0, 1, 2]
