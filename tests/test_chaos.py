"""Chaos drills (DESIGN.md §13): the central fault registry's trigger
semantics, plus crash/fault injection under LIVE traffic — transient
and hard faults mid-compaction and mid-checkpoint while a background
maintenance worker churns, a shard killed mid-rebalance, and a shard
hard-down served in degraded mode. Every drill asserts the always-on
invariants: zero dropped docs, zero duplicated docs, oracle-equivalent
results after recovery."""
import threading

import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.index.lsm import CompactionInterrupted, SegmentedIndex
from repro.serve.maintenance import StoreMaintenance
from repro.shard import (MigrationInterrupted, Rebalancer, ShardFabric,
                         results_equivalent)
from repro.testing.faults import FAULTS, FaultError, FaultRegistry

DIM = 64
CAP = 8192

VOCAB = ["alpha", "bravo", "carbon", "delta", "ember", "fjord",
         "glacier", "harbor", "isotope", "jetty", "kernel", "lagoon",
         "meadow", "nebula", "orchid", "plasma", "quartz", "rivet"]


@pytest.fixture(autouse=True)
def _clean_faults():
    FAULTS.reset()
    yield
    FAULTS.reset()


def make_stream(rng, n_docs=10, n_versions=2, chunks=2, words=5):
    stream, ts, texts = [], 0, {}
    for _ in range(n_versions):
        for i in range(n_docs):
            doc = f"doc{i}"
            if doc not in texts:
                texts[doc] = [" ".join(rng.choice(VOCAB, words))
                              for _ in range(chunks)]
            else:
                texts[doc][int(rng.integers(chunks))] = \
                    " ".join(rng.choice(VOCAB, words))
            ts += 1_000_000
            stream.append((doc, "\n\n".join(texts[doc]), ts))
    return stream


def drive(target, stream):
    for doc, text, ts in stream:
        target.ingest(doc, text, ts=ts)


def check_parity(oracle, target, queries, k=5, **kw):
    o = oracle.query_batch(queries, k=k, **kw)
    oe = oracle.query_batch(queries, k=4 * k, **kw)
    f = target.query_batch(queries, k=k, **kw)
    for qi in range(len(queries)):
        assert results_equivalent(o[qi], f[qi], oe[qi]), (
            [(r.doc_id, r.position, r.score) for r in o[qi]],
            [(r.doc_id, r.position, r.score) for r in f[qi]])


# ---------------------------------------------------------------------------
# fault registry semantics
# ---------------------------------------------------------------------------
class TestFaultRegistry:
    def test_default_rule_fires_first_call_once(self):
        reg = FaultRegistry()
        reg.arm("p")
        with pytest.raises(FaultError):
            reg.check("p")
        reg.check("p")                    # times=1: self-disarmed
        assert reg.fired("p") == 1

    def test_nth_trigger_then_fires_until_times_exhausted(self):
        reg = FaultRegistry()
        reg.arm("p", nth=2, times=2)
        reg.check("p")                    # call 1: below nth
        with pytest.raises(FaultError):
            reg.check("p")                # call 2: trips
        with pytest.raises(FaultError):
            reg.check("p")                # keeps firing (times=2)
        reg.check("p")                    # exhausted
        assert reg.fired("p") == 2
        assert reg.history == ["p", "p"]

    def test_probabilistic_replay_is_seed_deterministic(self):
        def run(seed):
            reg = FaultRegistry(seed=seed)
            reg.arm("p", prob=0.4, times=10**9)
            fires = []
            for _ in range(50):
                try:
                    reg.check("p")
                    fires.append(0)
                except FaultError:
                    fires.append(1)
            return fires

        assert run(7) == run(7)           # deterministic replay
        assert run(7) != run(8)           # and actually seed-sensitive
        assert 0 < sum(run(7)) < 50

    def test_prefix_rule_matches_any_suffix(self):
        reg = FaultRegistry()
        reg.arm("rebalance:copy:*", times=2)
        with pytest.raises(FaultError):
            reg.check("rebalance:copy:0")
        with pytest.raises(FaultError):
            reg.check("rebalance:copy:7")
        reg.check("rebalance:copy:8")     # exhausted
        reg.check("rebalance:before_flip")   # different point: no match

    def test_rule_exc_overrides_call_site_exc(self):
        reg = FaultRegistry()
        reg.arm("p", exc=KeyError)
        with pytest.raises(KeyError):
            reg.check("p", exc=ValueError)
        reg.arm("q")
        with pytest.raises(ValueError):
            reg.check("q", exc=ValueError)

    def test_disarm_reset_and_introspection(self):
        reg = FaultRegistry()
        reg.arm("a")
        reg.arm("b:*")
        assert reg.armed() == ["a", "b:*"]
        reg.disarm("a")
        reg.check("a")                    # disarmed: silent
        reg.reset()
        assert reg.armed() == [] and reg.fired() == 0

    def test_registry_matches_legacy_fail_at_shim(self, tmp_path):
        """Same crash, two switches: the legacy per-index ``fail_at``
        and the registry rule must interrupt the identical point with
        the identical exception type."""
        rng = np.random.default_rng(3)

        def filled(root):
            idx = SegmentedIndex(DIM, mem_capacity=4, root=root)
            from repro.core.types import ChunkRecord
            for i in range(3):
                emb = rng.standard_normal(DIM).astype(np.float32)
                emb /= np.linalg.norm(emb)
                idx.insert([ChunkRecord(
                    chunk_id=f"c{i}", doc_id="d", position=i,
                    text=f"t{i}", embedding=emb, valid_from=i + 1)])
            return idx

        legacy = filled(str(tmp_path / "legacy"))
        legacy.fail_at = "seal:before_manifest"
        with pytest.raises(CompactionInterrupted):
            legacy.seal()

        modern = filled(str(tmp_path / "modern"))
        FAULTS.arm("lsm:seal:before_manifest")
        with pytest.raises(CompactionInterrupted):
            modern.seal()


# ---------------------------------------------------------------------------
# store-level drills under background maintenance
# ---------------------------------------------------------------------------
class TestStoreChaos:
    def _pair(self, tmp_path, **maint_kw):
        oracle = LiveVectorLake(str(tmp_path / "oracle"), dim=DIM,
                                hot_capacity=CAP)
        root = str(tmp_path / "chaos")
        store = LiveVectorLake(root, dim=DIM, hot_capacity=8)
        maint = StoreMaintenance(store, backoff_s=1e-4,
                                 **maint_kw).start()
        return oracle, store, maint, root

    def test_transient_fault_mid_compaction_worker_retries(self, tmp_path):
        oracle, store, maint, _ = self._pair(tmp_path)
        FAULTS.arm("lsm:merge:before_manifest", times=1)   # transient
        rng = np.random.default_rng(11)
        stream = make_stream(rng, n_docs=14, n_versions=2)
        drive(oracle, stream)
        drive(store, stream)
        assert maint.drain(timeout=20.0)
        maint.stop()
        assert FAULTS.fired("lsm:merge:before_manifest") == 1
        assert maint.worker.last_error is None    # retry converged
        queries = [" ".join(rng.choice(VOCAB, 4)) for _ in range(6)]
        check_parity(oracle, store, queries)
        mid = stream[len(stream) // 2][2]
        check_parity(oracle, store, queries, at=mid)

    def test_hard_fault_mid_compaction_then_recovery(self, tmp_path):
        oracle, store, maint, root = self._pair(tmp_path)
        FAULTS.arm("lsm:merge:before_manifest", times=10**9)  # hard-down
        rng = np.random.default_rng(12)
        stream = make_stream(rng, n_docs=14, n_versions=2)
        drive(oracle, stream)
        drive(store, stream)
        assert maint.drain(timeout=20.0)
        # retries exhausted: loud failure, serving still correct
        assert maint.worker.last_error is not None
        queries = [" ".join(rng.choice(VOCAB, 4)) for _ in range(6)]
        check_parity(oracle, store, queries)
        maint.stop()
        FAULTS.reset()
        # crash-equivalent reopen: recovery converges, zero loss/dup
        re = LiveVectorLake(root, dim=DIM, hot_capacity=8)
        assert len(re.hot) == len(oracle.hot)
        check_parity(oracle, re, queries)
        check_parity(oracle, re, queries, at=stream[-1][2] // 2)

    def test_crash_mid_checkpoint_under_live_traffic(self, tmp_path):
        oracle, store, maint, root = self._pair(tmp_path,
                                                checkpoint_every=3)
        rng = np.random.default_rng(13)
        stream = make_stream(rng, n_docs=12, n_versions=2)
        drive(oracle, stream)   # before arming: FAULTS is process-wide
        FAULTS.arm("cold:checkpoint:data", times=1)        # transient
        errors = []

        def reader():
            try:
                for _ in range(30):
                    store.query("quartz rivet plasma", k=3)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        t = threading.Thread(target=reader)
        t.start()
        drive(store, stream)
        t.join(30.0)
        assert maint.drain(timeout=20.0)
        maint.stop()
        assert errors == []
        assert FAULTS.fired("cold:checkpoint:data") == 1
        assert store.cold.stats()["checkpoints"] >= 1      # retry landed
        queries = [" ".join(rng.choice(VOCAB, 4)) for _ in range(6)]
        check_parity(oracle, store, queries)
        re = LiveVectorLake(root, dim=DIM, hot_capacity=8)
        check_parity(oracle, re, queries, at=stream[-1][2] // 2)


# ---------------------------------------------------------------------------
# fabric drills: rebalance kill + shard hard-down
# ---------------------------------------------------------------------------
class TestFabricChaos:
    def test_kill_shard_mid_rebalance_under_live_traffic(self, tmp_path):
        rng = np.random.default_rng(21)
        stream = make_stream(rng, n_docs=12, n_versions=2)
        oracle = LiveVectorLake(str(tmp_path / "oracle"), dim=DIM,
                                hot_capacity=CAP)
        root = str(tmp_path / "fab")
        fab = ShardFabric(root, n_shards=2, dim=DIM, hot_capacity=CAP)
        drive(oracle, stream)
        drive(fab, stream)
        queries = [" ".join(rng.choice(VOCAB, 4)) for _ in range(6)]

        # kill the migration on its second doc copy
        FAULTS.arm("rebalance:copy:*", nth=2, times=1)
        with pytest.raises(MigrationInterrupted):
            Rebalancer(fab).split("s02")
        assert FAULTS.fired() == 1        # the drill really fired
        # old ring stays authoritative: serving continues mid-crash
        check_parity(oracle, fab, queries)

        # live traffic lands WHILE the transition is pending
        ts = stream[-1][2]
        oracle.ingest("doc0", "umbra vertex willow", ts=ts + 1_000_000)
        fab.ingest("doc0", "umbra vertex willow", ts=ts + 1_000_000)

        # crash-equivalent reopen rolls the migration forward
        fab2 = ShardFabric(root, dim=DIM)
        assert fab2.manifest.load()["transition"] is None
        assert "s02" in fab2.ring.shards
        assert sorted(fab2.all_docs()) == \
            sorted(oracle.hash_store.doc_ids())      # zero dropped docs
        check_parity(oracle, fab2, queries)          # zero duplicated:
        check_parity(oracle, fab2, queries, at=ts // 2)  # dedup == oracle

    def test_one_shard_down_serves_degraded_with_markers(self, tmp_path):
        rng = np.random.default_rng(22)
        stream = make_stream(rng, n_docs=12, n_versions=2)
        root = str(tmp_path / "fab")
        fab = ShardFabric(root, n_shards=4, dim=DIM, hot_capacity=CAP,
                          degraded_reads=True, shard_retries=1)
        drive(fab, stream)
        queries = [" ".join(rng.choice(VOCAB, 4)) for _ in range(6)]
        full = fab.query_batch(queries, k=5)
        full_ext = fab.query_batch(queries, k=40)   # extended pool

        dead = fab.ring.shards[1]
        FAULTS.arm(f"shard:{dead}:query", times=10**9)   # hard-down
        got = fab.query_batch(queries, k=5)
        lg = fab.planner.last_gather
        assert lg["degraded"] is True
        assert lg["shards_missing"] == [dead]
        health = fab.health()
        assert health["last_gather"]["degraded"] is True
        assert health["planner"]["degraded_gathers"] >= 1
        # retries were attempted before declaring the shard down
        assert health["planner"]["shard_retries"] >= 1
        # partial top-k: every degraded result is a true full-fabric
        # result (never fabricated — checked against the extended pool,
        # since surviving rows RANK HIGHER with less competition), and
        # most of the pool survives
        full_keys = {(r.doc_id, r.position, r.valid_from)
                     for row in full_ext for r in row}
        got_n = 0
        for row in got:
            for r in row:
                assert (r.doc_id, r.position, r.valid_from) in full_keys
                got_n += 1
        assert got_n >= 0.5 * sum(len(row) for row in full)

        # the serving batcher stamps member requests with the markers
        b = fab.query_batcher(k=5)
        reqs = [b.submit(q) for q in queries[:3]]
        b.drain()
        for r in reqs:
            assert r.done and r.error is None
            assert r.info.get("degraded") is True
            assert r.info.get("shards_missing") == [dead]

    def test_r1_without_degraded_mode_still_fails_loud(self, tmp_path):
        from repro.shard import ShardGatherError
        rng = np.random.default_rng(23)
        fab = ShardFabric(str(tmp_path / "fab"), n_shards=2, dim=DIM,
                          hot_capacity=CAP)
        drive(fab, make_stream(rng, n_docs=6, n_versions=1))
        FAULTS.arm(f"shard:{fab.ring.shards[0]}:query", times=10**9)
        with pytest.raises(ShardGatherError):
            fab.query_batch(["alpha bravo"], k=3)
