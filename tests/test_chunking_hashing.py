"""Layer 1 unit tests: semantic chunking + content-addressable hashing."""

from repro.core.chunking import chunk_document, reassemble, split_blocks
from repro.core.hashing import chunk_hash, normalize


class TestNormalize:
    def test_whitespace_invariance(self):
        assert normalize("Hello   World") == normalize("hello world")
        assert normalize("  a\tb  ") == normalize("A B")

    def test_newline_canonicalization(self):
        assert normalize("a\r\nb") == normalize("a\nb") == normalize("a\rb")

    def test_casefold(self):
        assert normalize("STRASSE") == normalize("strasse")

    def test_content_change_changes_hash(self):
        assert chunk_hash("the rate is 5%") != chunk_hash("the rate is 6%")

    def test_hash_deterministic(self):
        assert chunk_hash("abc") == chunk_hash("abc")
        assert len(chunk_hash("abc")) == 64


class TestChunking:
    def test_paragraph_split(self):
        doc = "Para one.\n\nPara two.\n\n\nPara three."
        blocks = split_blocks(doc)
        assert blocks == ["Para one.", "Para two.", "Para three."]

    def test_code_block_atomic(self):
        doc = "Intro.\n\n```python\ndef f():\n\n    return 1\n```\n\nOutro."
        blocks = split_blocks(doc)
        assert len(blocks) == 3
        assert blocks[1].startswith("```python")
        assert "return 1" in blocks[1]

    def test_table_atomic(self):
        doc = "Before.\n\n| a | b |\n|---|---|\n| 1 | 2 |\n\nAfter."
        blocks = split_blocks(doc)
        assert len(blocks) == 3
        assert blocks[1].count("|") >= 6

    def test_list_atomic(self):
        doc = "Head.\n\n- item one\n- item two\n\n- item three\n\nTail."
        blocks = split_blocks(doc)
        # list items merge into ONE atomic block even across blank lines
        assert len(blocks) == 3

    def test_positions_and_reassembly(self):
        doc = "A.\n\nB.\n\nC."
        chunks = chunk_document(doc)
        assert [c.position for c in chunks] == [0, 1, 2]
        assert reassemble(chunks) == "A.\n\nB.\n\nC."

    def test_identical_content_identical_id(self):
        c1 = chunk_document("Same paragraph.")[0]
        c2 = chunk_document("Other.\n\nSame   PARAGRAPH.")[1]
        assert c1.chunk_id == c2.chunk_id

    def test_empty_doc(self):
        assert chunk_document("") == []
        assert chunk_document("\n\n\n") == []
