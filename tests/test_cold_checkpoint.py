"""Cold-tier checkpoint / zone-map / archive tests (DESIGN.md §9).

The invariant under test everywhere: a snapshot served through the
overlays (checkpoint seed + archive pruning) is record-for-record
identical — same rows, same order, same valid_to — to the from-scratch
O(total history) log fold, at every instant and version, in both
include_closed modes."""
import os

import numpy as np

from repro.core.cold_tier import ColdTier
from repro.core.types import ChunkRecord, VALID_TO_OPEN


def _rec(doc, pos, text, ts, dim=8):
    rng = np.random.default_rng(abs(hash((doc, pos, text))) % 2**31)
    e = rng.standard_normal(dim).astype(np.float32)
    e /= np.linalg.norm(e)
    return ChunkRecord(chunk_id=f"h-{doc}-{pos}-{ts}", doc_id=doc,
                       position=pos, valid_from=ts, text=text, embedding=e)


def _assert_snap_identical(a, b, tag=""):
    assert a.chunk_ids == b.chunk_ids, tag
    np.testing.assert_array_equal(a.valid_from, b.valid_from, err_msg=tag)
    np.testing.assert_array_equal(a.valid_to, b.valid_to, err_msg=tag)
    np.testing.assert_array_equal(a.embeddings, b.embeddings, err_msg=tag)
    np.testing.assert_array_equal(a.version, b.version, err_msg=tag)
    np.testing.assert_array_equal(a.position, b.position, err_msg=tag)
    assert a.doc_ids == b.doc_ids and a.texts == b.texts, tag
    assert a.as_of == b.as_of, tag


def _build(ct, n_versions=12, n_docs=3, t0=1000, dt=100):
    """n_versions supersede cycles over n_docs docs, one commit each."""
    ts = t0
    for v in range(n_versions):
        doc = f"d{v % n_docs}"
        closures = []
        if v >= n_docs:
            closures = [{"doc_id": doc, "position": 0, "closed_at": ts,
                         "status": "superseded"}]
        ct.commit([_rec(doc, 0, f"text v{v}", ts)], closures, ts)
        ts += dt
    return ts


class TestCheckpoints:
    def test_written_at_interval(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=4)
        _build(ct, n_versions=10)
        assert [m["version"] for m in ct.checkpoints()] == [4, 8]

    def test_snapshot_equals_scratch_fold_on_grid(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=3)
        end = _build(ct, n_versions=14)
        for ts in range(950, end + 50, 37):
            for inc in (False, True):
                _assert_snap_identical(
                    ct.snapshot(as_of_ts=ts, include_closed=inc),
                    ct.snapshot(as_of_ts=ts, include_closed=inc,
                                from_scratch=True),
                    f"ts={ts} inc={inc}")

    def test_version_targeted_snapshot_equals_scratch(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=4)
        _build(ct, n_versions=11)
        for v in range(1, 12):
            _assert_snap_identical(
                ct.snapshot(version=v, include_closed=True),
                ct.snapshot(version=v, include_closed=True,
                            from_scratch=True), f"v={v}")

    def test_delta_fold_loads_only_delta_segments(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=8)
        end = _build(ct, n_versions=17)     # checkpoints at 8, 16
        ct.io_counters["segment_loads"] = 0
        ct.io_counters["checkpoint_loads"] = 0
        ct.snapshot(as_of_ts=end)
        # seeded from ckpt@16: only the v17 segment is re-read
        assert ct.io_counters["segment_loads"] == 1
        assert ct.io_counters["checkpoint_loads"] == 1

    def test_corrupt_checkpoint_falls_back_to_fold(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=4)
        end = _build(ct, n_versions=8)
        npz = os.path.join(str(tmp_path), "_ckpt", "ckpt-00000008.npz")
        with open(npz, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))
        _assert_snap_identical(ct.snapshot(as_of_ts=end),
                               ct.snapshot(as_of_ts=end, from_scratch=True))

    def test_mark_committed_invalidates_checkpoints(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=2)
        _build(ct, n_versions=6)
        assert len(ct.checkpoints()) == 3
        ct.mark_committed(3, committed=False)
        # every checkpoint that baked version >= 3 is gone
        assert [m["version"] for m in ct.checkpoints()] == [2]
        _assert_snap_identical(ct.snapshot(include_closed=True),
                               ct.snapshot(include_closed=True,
                                           from_scratch=True))
        ct.mark_committed(3, committed=True)
        _assert_snap_identical(ct.snapshot(include_closed=True),
                               ct.snapshot(include_closed=True,
                                           from_scratch=True))

    def test_orphan_checkpoint_swept_on_init(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=4)
        _build(ct, n_versions=4)
        npz, meta = os.path.join(str(tmp_path), "_ckpt", "ckpt-00000004.npz"), \
            os.path.join(str(tmp_path), "_ckpt", "ckpt-00000004.json")
        os.unlink(meta)                      # simulate crash before meta
        ct2 = ColdTier(str(tmp_path), dim=8)
        assert not os.path.exists(npz)       # orphan swept
        assert ct2.checkpoints() == []


class TestArchives:
    def test_compact_archives_fully_closed_runs(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        end = _build(ct, n_versions=12, n_docs=2)
        r = ct.compact()
        assert r["archived_runs"] >= 1 and r["archived_rows"] > 0
        # still-open rows (last version per doc) are never archived
        arcs = ct.archives()
        assert all(a["vt_max"] != VALID_TO_OPEN for a in arcs)
        for ts in range(950, end + 50, 23):
            for inc in (False, True):
                _assert_snap_identical(
                    ct.snapshot(as_of_ts=ts, include_closed=inc),
                    ct.snapshot(as_of_ts=ts, include_closed=inc,
                                from_scratch=True), f"ts={ts} inc={inc}")

    def test_zone_prune_skips_dead_archives(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        end = _build(ct, n_versions=12, n_docs=2)
        ct.compact()
        ct.io_counters["archive_loads"] = 0
        ct.io_counters["archives_pruned"] = 0
        # far past every closure in the archive: zone map proves no row
        # can be valid, so the .npz is never opened
        snap = ct.snapshot(as_of_ts=end + 10**6)
        assert ct.io_counters["archives_pruned"] == 1
        assert ct.io_counters["archive_loads"] == 0
        assert all(vt == VALID_TO_OPEN for vt in snap.valid_to)

    def test_time_travel_inside_archived_run(self, tmp_path):
        """Snapshot at a version INSIDE an archived run falls back to the
        retained per-commit segments."""
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        _build(ct, n_versions=10, n_docs=2)
        ct.compact()
        lo, hi = ct.archives()[0]["lo"], ct.archives()[0]["hi"]
        v_mid = (lo + hi) // 2
        _assert_snap_identical(
            ct.snapshot(version=v_mid, include_closed=True),
            ct.snapshot(version=v_mid, include_closed=True,
                        from_scratch=True))

    def test_archive_does_not_leak_future_closures(self, tmp_path):
        """A fold cut BEFORE a run row's closing entry must see the row
        open (valid_to == OPEN), even when the archive baked the final
        closure."""
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        _build(ct, n_versions=10, n_docs=2)
        ct.compact()
        a = ct.archives()[0]
        # pick an instant before the archive's last closure lands
        ts = a["vt_max"] - 1
        s_overlay = ct.snapshot(as_of_ts=ts, include_closed=True)
        s_scratch = ct.snapshot(as_of_ts=ts, include_closed=True,
                                from_scratch=True)
        _assert_snap_identical(s_overlay, s_scratch)
        assert VALID_TO_OPEN in s_scratch.valid_to.tolist()

    def test_mark_committed_drops_dependent_archives(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        _build(ct, n_versions=10, n_docs=2)
        ct.compact()
        assert ct.archives()
        # the archive consumed closures from the tail versions; flipping
        # one of those must drop it (and its npz)
        consumed_versions = [v for a in ct.archives() for v, _ in
                             a["consumed"]]
        v_flip = min(consumed_versions)
        ct.mark_committed(v_flip, committed=False)
        assert not ct.archives()
        arc_dir = os.path.join(str(tmp_path), "_archive")
        assert [f for f in os.listdir(arc_dir) if f.endswith(".npz")] == []
        _assert_snap_identical(ct.snapshot(include_closed=True),
                               ct.snapshot(include_closed=True,
                                           from_scratch=True))

    def test_compact_idempotent(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        _build(ct, n_versions=10, n_docs=2)
        r1 = ct.compact()
        r2 = ct.compact()                    # covered runs not re-archived
        assert r1["archived_runs"] >= 1 and r2["archived_runs"] == 0


class TestZoneMapsAndHistory:
    def test_log_entries_carry_zone_maps(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("a", 0, "x", 100), _rec("b", 1, "y", 150)], [], 150)
        e = ct.read_entries(1, 1)[0]
        assert e["zone"]["vf_min"] == 100 and e["zone"]["vf_max"] == 150
        assert sorted(tuple(k) for k in e["zone"]["keys"]) == \
            [("a", 0), ("b", 1)]

    def test_history_is_doc_scoped_and_prunes(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        _build(ct, n_versions=12, n_docs=3)
        full = ct.snapshot(include_closed=True, from_scratch=True)
        ct.io_counters["segment_loads"] = 0
        ct.io_counters["segments_pruned"] = 0
        h = ct.history("d1")
        n_d1 = sum(1 for d in full.doc_ids if d == "d1")
        assert len(h) == n_d1
        # only d1's segments were opened; the rest pruned via zone keys
        assert ct.io_counters["segments_pruned"] > 0
        assert ct.io_counters["segment_loads"] == n_d1

    def test_history_matches_full_fold_contents(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=4)
        _build(ct, n_versions=12, n_docs=3)
        ct.compact()
        full = ct.snapshot(include_closed=True, from_scratch=True)
        for doc in ("d0", "d1", "d2"):
            h = ct.history(doc)
            ref = sorted(
                ((int(full.position[i]), int(full.valid_from[i]),
                  int(full.valid_to[i]), full.chunk_ids[i])
                 for i in range(len(full)) if full.doc_ids[i] == doc))
            got = [(r["position"], r["valid_from"], r["valid_to"],
                    r["chunk_id"]) for r in h]
            assert got == ref

    def test_history_after_compaction_prunes_archives(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8, checkpoint_interval=0)
        # two docs with disjoint lifetimes: archive zone doc-lists prune
        ts = 1000
        for v in range(8):
            ct.commit([_rec("only-a", 0, f"a{v}", ts)],
                      [] if v == 0 else
                      [{"doc_id": "only-a", "position": 0,
                        "closed_at": ts, "status": "superseded"}], ts)
            ts += 100
        ct.commit([_rec("only-b", 0, "b0", ts)], [], ts)
        ct.compact()
        assert ct.archives()
        ct.io_counters["archive_loads"] = 0
        h = ct.history("only-b")
        assert len(h) == 1 and h[0]["status"] == "active"
        assert ct.io_counters["archive_loads"] == 0   # pruned by doc set
