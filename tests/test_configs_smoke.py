"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family config, runs one real step on CPU for every assigned
shape — asserting output shapes and no NaNs. The FULL configs are
exercised via the dry-run only (ShapeDtypeStruct, no allocation)."""
import jax
import numpy as np
import pytest

from repro.configs import all_cells, get_arch, list_archs
from repro.launch.steps import build_cell, make_smoke_args

ASSIGNED = [
    "mistral-nemo-12b", "nemotron-4-15b", "qwen1.5-32b", "kimi-k2-1t-a32b",
    "qwen2-moe-a2.7b", "schnet", "fm", "bert4rec", "dlrm-mlperf",
    "wide-deep",
]


def test_registry_complete():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs
    assert "minilm-embedder" in archs        # the paper's own model
    cells = [c for c in all_cells() if c.arch in ASSIGNED]
    assert len(cells) == 40                  # the assigned matrix


def test_full_config_param_counts():
    """Exact configs match their public param-count claims."""
    cases = {
        "mistral-nemo-12b": (11e9, 14e9),
        "nemotron-4-15b": (14e9, 17e9),
        "qwen1.5-32b": (30e9, 37e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "qwen2-moe-a2.7b": (12e9, 16e9),
    }
    for name, (lo, hi) in cases.items():
        cfg = get_arch(name).model_config(False)
        n = cfg.n_params()
        assert lo <= n <= hi, f"{name}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"
    # active params of the MoEs
    kimi = get_arch("kimi-k2-1t-a32b").model_config(False)
    assert 25e9 <= kimi.n_active_params() <= 40e9
    qmoe = get_arch("qwen2-moe-a2.7b").model_config(False)
    assert 2e9 <= qmoe.n_active_params() <= 4e9


def _finite(tree) -> bool:
    return all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "dtype") and np.issubdtype(l.dtype, np.floating))


@pytest.mark.parametrize("cell", [c for c in all_cells()
                                  if c.arch in ASSIGNED],
                         ids=lambda c: c.key)
def test_cell_smoke(cell):
    """Reduced config, real arrays, one step on CPU: shapes + finiteness."""
    bundle = build_cell(cell.arch, cell.shape, reduced=True)
    args = make_smoke_args(bundle)
    out = bundle.fn(*args)
    assert _finite(out), f"{cell.key}: non-finite output"
    if bundle.kind == "train":
        new_p, new_o, loss = out[0], out[1], out[-1]
        assert np.isfinite(float(loss))
        # params must actually change
        before = jax.tree_util.tree_leaves(args[0])[0]
        after = jax.tree_util.tree_leaves(new_p)[0]
        assert not np.allclose(np.asarray(before), np.asarray(after))
    elif bundle.kind == "decode":
        logits = out[0]
        b = bundle.arg_specs[1]["tokens"].shape[0]
        assert logits.shape == (b, bundle.model_cfg.vocab)
        assert int(out[-1]) == 3             # cache_len advanced (2 + 1)
    elif bundle.kind == "prefill":
        logits = out[0]
        assert logits.shape[-1] == bundle.model_cfg.vocab
    elif bundle.kind == "retrieval":
        scores, ids = out
        assert scores.shape == ids.shape
        s = np.asarray(scores)
        assert np.all(np.diff(s, axis=1) <= 1e-6)   # sorted desc


def test_embedder_cells_smoke():
    for shape in ("encode_corpus", "encode_query"):
        bundle = build_cell("minilm-embedder", shape, reduced=True)
        args = make_smoke_args(bundle)
        vecs = bundle.fn(*args)
        assert vecs.shape[-1] == bundle.model_cfg.d_model
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(vecs, np.float32), axis=-1), 1.0,
            rtol=1e-3)
