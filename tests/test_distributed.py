"""Multi-device semantics tests: run subprocesses with 8 forced host
devices (XLA_FLAGS must precede jax import, so in-process is not an
option) and verify distributed == single-device results."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_sharded_moe_matches_dense_ref():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import (MoEConfig, moe_params,
                                      moe_block_sharded, moe_block_dense_ref)
        from repro.launch.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=1,
                        capacity_factor=16.0)   # drop-free
        d = 32
        params = moe_params(jax.random.PRNGKey(0), d, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))
        with mesh:
            out_s, aux_s = jax.jit(
                lambda p, x: moe_block_sharded(p, x, cfg, mesh))(params, x)
        ref = moe_block_dense_ref(params, x, cfg)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        assert np.isfinite(float(aux_s))
        print("MOE_OK")
    """)
    assert "MOE_OK" in out


def test_sharded_moe_grads_finite():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.moe import (MoEConfig, moe_params,
                                      moe_block_sharded)
        from repro.launch.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=4.0)
        params = moe_params(jax.random.PRNGKey(0), 32, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
        def loss(p):
            out, aux = moe_block_sharded(p, x, cfg, mesh)
            return jnp.sum(out ** 2) + aux
        with mesh:
            g = jax.jit(jax.grad(loss))(params)
        ok = all(np.all(np.isfinite(np.asarray(v)))
                 for v in jax.tree_util.tree_leaves(g))
        nz = any(np.any(np.asarray(v) != 0)
                 for v in jax.tree_util.tree_leaves(g))
        assert ok and nz
        print("GRADS_OK")
    """)
    assert "GRADS_OK" in out


def test_lm_train_step_sharded_runs():
    """A reduced MoE train step executes on a real 2x4 mesh with the
    production sharding rules, and loss decreases over steps."""
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.steps import build_cell, make_smoke_args
        from repro.launch import sharding as shd
        from jax.sharding import PartitionSpec as P
        from repro.launch.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b = build_cell("qwen2-moe-a2.7b", "train_4k", reduced=True)
        args = make_smoke_args(b)
        in_sh = jax.tree.map(lambda s: shd.named(mesh, s),
                             b.sharding_fn(mesh),
                             is_leaf=lambda x: isinstance(x, P))
        with mesh:
            step = jax.jit(b.fn, in_shardings=in_sh,
                           out_shardings=(in_sh[0], in_sh[1], None))
            params, opt, batch, i = jax.tree.map(
                lambda a, s: jax.device_put(a, s), args, in_sh)
            losses = []
            for t in range(8):
                params, opt, loss = step(params, opt, batch,
                                         jnp.asarray(t))
                losses.append(float(loss))
        assert losses[-1] < losses[0]
        print("TRAIN_OK", losses[0], losses[-1])
    """)
    assert "TRAIN_OK" in out


def test_elastic_checkpoint_across_device_counts():
    """Save on 8 devices (2x4 mesh, sharded), restore on 1 device."""
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        run_devices(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train.checkpoint import CheckpointManager
            from repro.launch.compat import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            w = jnp.arange(64.0).reshape(8, 8)
            w = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
            CheckpointManager({root!r}).save(5, {{"w": w}})
            print("SAVED")
        """, n_devices=8)
        out = run_devices(f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro.train.checkpoint import CheckpointManager
            tree = {{"w": jnp.zeros((8, 8))}}
            restored, step, _ = CheckpointManager({root!r}).restore(tree)
            np.testing.assert_array_equal(
                np.asarray(restored["w"]),
                np.arange(64.0).reshape(8, 8))
            print("RESTORED", step)
        """, n_devices=1)
        assert "RESTORED 5" in out


def test_retrieval_shard_map_matches_local():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.steps import build_cell
        from repro.launch import sharding as shd
        from jax.sharding import PartitionSpec as P
        from repro.kernels.topk_search.ref import topk_search_ref
        from repro.launch.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b = build_cell("fm", "retrieval_cand", reduced=True)
        rng = np.random.default_rng(0)
        n, d = b.arg_specs[0]["candidates"].shape
        cands = rng.standard_normal((n, d)).astype(np.float32)
        cands /= np.linalg.norm(cands, axis=1, keepdims=True)
        q = cands[7:8]
        mask = np.ones(n, bool); mask[-5:] = False
        batch = {"query": jnp.asarray(q),
                 "candidates": jnp.asarray(cands),
                 "candidate_mask": jnp.asarray(mask)}
        fn = b.fn_factory(mesh)
        with mesh:
            s, i = jax.jit(fn)(batch)
        k = s.shape[1]
        s_ref, i_ref = topk_search_ref(jnp.asarray(q), jnp.asarray(cands),
                                       jnp.asarray(mask), k)
        np.testing.assert_allclose(np.asarray(s)[0], np.asarray(s_ref)[0],
                                   rtol=1e-5, atol=1e-5)
        assert int(np.asarray(i)[0, 0]) == 7
        print("RETRIEVAL_OK")
    """)
    assert "RETRIEVAL_OK" in out


def test_gqa_decode_sequence_sharded_matches_ref():
    out = run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.kernels.flash_decode.ref import decode_attention_ref
        from repro.launch.compat import make_mesh
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        b, h, kv, s, dh = 2, 8, 2, 64, 16
        q = jnp.asarray(rng.standard_normal((b, h, dh)).astype(np.float32))
        kc = jnp.asarray(rng.standard_normal((b, kv, s, dh)).astype(np.float32))
        vc = jnp.asarray(rng.standard_normal((b, kv, s, dh)).astype(np.float32))
        ref = decode_attention_ref(q, kc, vc,
                                   jnp.full((b,), 50, jnp.int32))
        # sequence-sharded cache (the long_500k layout)
        sh = NamedSharding(mesh, P(None, None, "model", None))
        kc_s, vc_s = jax.device_put(kc, sh), jax.device_put(vc, sh)
        with mesh:
            out = jax.jit(decode_attention_ref)(
                q, kc_s, vc_s, jnp.full((b,), 50, jnp.int32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("DECODE_OK")
    """)
    assert "DECODE_OK" in out
