"""Export-surface tests (src/repro/obs/export.py — DESIGN.md §15):
Prometheus text round-trip, OTLP span-tree round-trip, determinism,
the pull endpoint, and the golden files under tests/golden/ that lock
both exposition formats (CI checks the same fixture without pytest via
``python -m repro.obs.export --check-golden``)."""
import json
import os
import urllib.error
import urllib.request

import pytest

from repro import obs
from repro.obs import MetricsRegistry
from repro.obs.export import (GOLDEN_FILES, ObsHttpServer, golden_fixture,
                              parse_prometheus_text, prometheus_text,
                              trace_from_otlp, trace_to_otlp)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(autouse=True)
def _clean():
    obs.set_enabled(True)
    obs.SLOW_QUERIES.reset()
    obs.SLO_ENGINE.reset()
    obs.FLIGHT_RECORDER.disable()
    obs.FLIGHT_RECORDER.reset()
    yield
    obs.SLO_ENGINE.reset()
    obs.FLIGHT_RECORDER.disable()
    obs.FLIGHT_RECORDER.reset()


def _registry():
    reg = MetricsRegistry()
    reg.counter("scan_row_reads", source="fused").inc(4096)
    reg.counter("scan_row_reads", tenant="acme").inc(1234)
    reg.gauge("slo_burn_rate", tenant="acme", intent="current",
              window="60s").set(2.625)
    h = reg.histogram("trace_ms", bounds=[1.0, 10.0, 100.0], trace="batch")
    for v in (0.5, 2.0, 5.0, 50.0, 500.0):
        h.observe(v)
    return reg


class TestPrometheusRoundTrip:
    def test_values_survive_serialize_parse(self):
        reg = _registry()
        parsed = parse_prometheus_text(prometheus_text(reg))
        assert parsed["counters"][
            "scan_row_reads{source=fused}"] == 4096
        assert parsed["counters"][
            "scan_row_reads{tenant=acme}"] == 1234
        assert parsed["gauges"][
            "slo_burn_rate{intent=current,tenant=acme,window=60s}"] \
            == 2.625
        h = parsed["histograms"]["trace_ms{trace=batch}"]
        assert h["count"] == 5
        assert h["sum"] == pytest.approx(557.5)
        # buckets are CUMULATIVE per the exposition format
        assert h["buckets"] == {"1.0": 1, "10.0": 3, "100.0": 4,
                                "+Inf": 5}

    def test_label_values_escaped(self):
        reg = MetricsRegistry()
        reg.counter("odd", tag='a"b\\c').inc(1)
        text = prometheus_text(reg)
        assert '\\"' in text and "\\\\" in text
        parsed = parse_prometheus_text(text)
        assert parsed["counters"]['odd{tag=a"b\\c}'] == 1

    def test_float_values_roundtrip_exactly(self):
        reg = MetricsRegistry()
        reg.gauge("g").set(0.1 + 0.2)    # classic repr stress value
        parsed = parse_prometheus_text(prometheus_text(reg))
        assert parsed["gauges"]["g"] == 0.1 + 0.2


class TestOtlpRoundTrip:
    def _trace(self):
        with obs.trace("batch", intent="current", tenant="acme") as root:
            root.add("batch_size", 8)
            root.add("queue_wait_ms", 1.5)
            with obs.span("plan"):
                with obs.span("shard:s00"):
                    with obs.span("kernel:topk_search_q8") as k:
                        k.add("rows", 65536)
                        k.add("bytes_streamed", 8_388_608)
                try:
                    with obs.span("shard:s01"):
                        raise RuntimeError("boom")
                except RuntimeError:
                    pass
        return obs.SLOW_QUERIES.slowest.to_dict()

    def test_span_tree_round_trips(self):
        d = self._trace()
        back = trace_from_otlp(trace_to_otlp(d))
        assert back == d        # names, nesting, counters, statuses,
        #                         intent and trace attrs — everything
        #                         to_dict() emits

    def test_deterministic_bytes(self):
        d = self._trace()
        a = json.dumps(trace_to_otlp(d), sort_keys=True)
        b = json.dumps(trace_to_otlp(d), sort_keys=True)
        assert a == b

    def test_sibling_times_packed_end_to_end(self):
        d = {"name": "r", "intent": None, "wall_ms": 3.0,
             "spans": {"name": "r", "wall_ms": 3.0, "children": [
                 {"name": "a", "wall_ms": 1.0},
                 {"name": "b", "wall_ms": 2.0}]}}
        spans = trace_to_otlp(d)["resourceSpans"][0]["scopeSpans"][0][
            "spans"]
        by_name = {s["name"]: s for s in spans}
        assert by_name["r"]["startTimeUnixNano"] == "0"
        assert by_name["a"]["startTimeUnixNano"] == "0"
        assert by_name["b"]["startTimeUnixNano"] == \
            by_name["a"]["endTimeUnixNano"] == "1000000"
        assert by_name["a"]["parentSpanId"] == by_name["r"]["spanId"]

    def test_error_status_carried(self):
        d = self._trace()
        otlp = trace_to_otlp(d)
        spans = otlp["resourceSpans"][0]["scopeSpans"][0]["spans"]
        (bad,) = [s for s in spans if s["name"] == "shard:s01"]
        assert bad["status"] == {"code": "STATUS_CODE_ERROR",
                                 "message": "error:RuntimeError"}


class TestHttpEndpoint:
    def _get(self, server, path):
        with urllib.request.urlopen(server.url(path), timeout=5) as r:
            return r.status, r.headers.get("Content-Type"), \
                r.read().decode()

    def test_all_routes(self):
        obs.SLO_ENGINE.declare("acme", "current", latency_ms=50.0,
                               target=0.99)
        obs.FLIGHT_RECORDER.enable(capacity=8, sample_rate=1.0)
        with obs.trace("request", intent="current", tenant="acme"):
            pass
        server = ObsHttpServer(
            health_fn=lambda: {"ok": True, "shards": 2}).start()
        try:
            code, _, body = self._get(server, "/slo")
            slo = json.loads(body)
            assert code == 200 and slo["declared"] == 1
            assert slo["slos"][0]["tenant"] == "acme"
            # evaluating /slo published the burn gauges; the /metrics
            # scrape that follows (real scrape order) sees them
            code, ctype, body = self._get(server, "/metrics")
            assert code == 200 and ctype.startswith("text/plain")
            parsed = parse_prometheus_text(body)
            assert any(k.startswith("slo_burn_rate{")
                       for k in parsed["gauges"])
            code, _, body = self._get(server, "/traces")
            traces = json.loads(body)
            assert code == 200 and traces["summary"]["retained"] == 1
            assert traces["records"][0]["attrs"]["tenant"] == "acme"
            code, _, body = self._get(server, "/health")
            assert code == 200 and json.loads(body)["shards"] == 2
            with pytest.raises(urllib.error.HTTPError) as ei:
                self._get(server, "/nope")
            assert ei.value.code == 404
        finally:
            server.stop()
        # cleanup for the histogram this test adds is unnecessary: the
        # process-wide registry tolerates extra labeled series


class TestGoldenFiles:
    """The same fixture CI checks via
    ``python -m repro.obs.export --check-golden tests/golden`` —
    a mismatch means the exposition format or the cost math drifted."""

    def test_goldens_exist_and_match(self):
        prom, otlp = golden_fixture()
        rendered = dict(zip(GOLDEN_FILES, (prom, otlp)))
        for fname, body in rendered.items():
            path = os.path.join(GOLDEN_DIR, fname)
            with open(path) as f:
                assert f.read() == body, \
                    f"{fname} drifted — regenerate with " \
                    f"python -m repro.obs.export --write-golden tests/golden"

    def test_fixture_locks_cost_math(self):
        _, otlp = golden_fixture()
        doc = json.loads(otlp)
        spans = doc["resourceSpans"][0]["scopeSpans"][0]["spans"]
        (k,) = [s for s in spans if s["name"] == "kernel:topk_search_q8"]
        attrs = {a["key"]: a["value"] for a in k["attributes"]}
        # 8 MiB in 8 ms = 1.0486 GB/s; fraction of the 819 GB/s roofline
        assert attrs["achieved_gbs"]["doubleValue"] == \
            pytest.approx(1.0486, rel=1e-3)
        assert attrs["roofline_frac"]["doubleValue"] == \
            pytest.approx(1.0486 / obs.PEAK_HBM_GBS, rel=1e-3)
