"""Flight-recorder tests (src/repro/obs/recorder.py — DESIGN.md §15):
trace classification, the tail-sampling retention INVARIANT (an
interesting trace is never evicted while a sampled-ok one remains),
deterministic sampling, cost annotation of retained records, JSONL
dumps, and the fault-registry autodump under a chaos battery — every
armed fault must leave a black-box artifact."""
import json

import pytest

from repro import obs
from repro.obs.recorder import FlightRecorder, classify_trace
from repro.obs.trace import Span, Trace
from repro.testing.faults import FAULTS, FaultError


def _tr(name="request", intent="current", wall_ms=5.0, status="ok",
        **attrs):
    tr = Trace(name, intent, attrs=attrs or None)
    tr.wall_ms = tr.root.wall_ms = wall_ms
    tr.root.status = status
    return tr


@pytest.fixture(autouse=True)
def _clean():
    obs.set_enabled(True)
    obs.SLOW_QUERIES.reset()
    obs.FLIGHT_RECORDER.disable()
    obs.FLIGHT_RECORDER.reset()
    FAULTS.reset()
    yield
    obs.FLIGHT_RECORDER.disable()
    obs.FLIGHT_RECORDER.reset()
    obs.SLOW_QUERIES.reset()
    FAULTS.reset()


class TestClassification:
    def test_outcomes(self):
        assert classify_trace(_tr(status="error:ValueError")) == "error"
        assert classify_trace(
            _tr(status="error:DeadlineExceeded")) == "deadline"
        assert classify_trace(_tr(degraded=True)) == "degraded"
        assert classify_trace(_tr(wall_ms=500.0)) == "over_budget"
        assert classify_trace(_tr(wall_ms=5.0)) is None

    def test_over_budget_respects_intent_budgets(self):
        # maintenance gets its 10s default budget, not the global 100ms
        assert classify_trace(
            _tr(name="maint:compact", intent="maintenance",
                wall_ms=500.0)) is None
        assert classify_trace(
            _tr(name="maint:compact", intent="maintenance",
                wall_ms=20_000.0)) == "over_budget"


class TestRetention:
    def test_sampled_evicted_before_any_interesting(self):
        rec = FlightRecorder(capacity=8, sample_rate=1.0)
        rec.enabled = True
        for i in range(4):
            rec.observe_trace(_tr(status="error:ValueError"))
        for i in range(10):
            rec.observe_trace(_tr())       # sampled-ok at rate 1.0
        # 14 observed into capacity 8: only sampled-ok records evicted
        assert rec.evicted == {"sampled": 6, "interesting": 0}
        reasons = [r["reason"] for r in rec.records()]
        assert reasons.count("error") == 4

    def test_error_never_evicted_while_sampled_remain(self):
        rec = FlightRecorder(capacity=8, sample_rate=1.0)
        rec.enabled = True
        rec.observe_trace(_tr(status="error:ValueError"))   # seq 1
        for _ in range(20):        # interleave: ok, error, ok, error...
            rec.observe_trace(_tr())
            rec.observe_trace(_tr(status="error:ValueError"))
        # interesting alone overflows capacity, so the oldest errors DO
        # eventually go — but never while a sampled-ok record remained
        assert rec.summary()["sampled"] == 0
        assert rec.evicted["interesting"] > 0
        assert all(r["reason"] == "error" for r in rec.records())

    def test_seeded_sampling_is_deterministic(self):
        kept = []
        for _ in range(2):
            rec = FlightRecorder(capacity=64, sample_rate=0.3, seed=7)
            rec.enabled = True
            for _ in range(50):
                rec.observe_trace(_tr())
            kept.append([r["seq"] for r in rec.records()])
        assert kept[0] == kept[1]
        assert 0 < len(kept[0]) < 50

    def test_rate_zero_keeps_only_interesting(self):
        rec = FlightRecorder(capacity=64, sample_rate=0.0)
        rec.enabled = True
        for _ in range(10):
            rec.observe_trace(_tr())
        rec.observe_trace(_tr(status="error:ValueError"))
        assert rec.dropped == 10
        assert [r["reason"] for r in rec.records()] == ["error"]

    def test_events_always_interesting(self):
        rec = FlightRecorder(capacity=8, sample_rate=0.0)
        rec.enabled = True
        rec.observe_event("admission_rejected", tenant="acme",
                          detail="queue_full")
        (r,) = rec.records()
        assert r["kind"] == "event"
        assert r["reason"] == "admission_rejected"
        assert r["attrs"]["tenant"] == "acme"

    def test_disabled_recorder_records_nothing(self):
        rec = FlightRecorder()
        rec.observe_trace(_tr(status="error:ValueError"))
        rec.observe_event("admission_rejected")
        assert rec.records() == []


class TestCostAnnotation:
    def _kernel_trace(self, queue_ms=0.0, kernel_ms=9.0, wall_ms=10.0):
        tr = _tr(wall_ms=wall_ms, status="error:ValueError")
        if queue_ms:
            tr.root.counters["queue_wait_ms"] = queue_ms
        tr.root.children.append(
            Span("kernel:topk_search_q8", wall_ms=kernel_ms,
                 counters={"bytes_streamed": 8_388_608}))
        return tr

    def test_retained_records_carry_roofline_numbers(self):
        rec = FlightRecorder(capacity=8)
        rec.enabled = True
        rec.observe_trace(self._kernel_trace())
        (r,) = rec.records()
        k = r["spans"]["children"][0]["counters"]
        # 8 MiB in 9ms ≈ 0.932 GB/s
        assert k["achieved_gbs"] == pytest.approx(0.932, rel=0.01)
        assert k["roofline_frac"] == pytest.approx(
            k["achieved_gbs"] / obs.PEAK_HBM_GBS, rel=1e-3)
        assert r["cost"]["bound"] == "bandwidth-bound"
        assert r["cost"]["kernel_frac"] == pytest.approx(0.9, rel=0.01)

    def test_bound_verdicts(self):
        rec = FlightRecorder(capacity=8)
        rec.enabled = True
        rec.observe_trace(self._kernel_trace(queue_ms=6.0))
        rec.observe_trace(self._kernel_trace(kernel_ms=2.0))
        a, b = rec.records()
        assert a["cost"]["bound"] == "queue-bound"
        assert b["cost"]["bound"] == "dispatch-bound"


class TestDumps:
    def test_dump_writes_jsonl(self, tmp_path):
        rec = FlightRecorder(capacity=8)
        rec.enabled = True
        rec.observe_trace(_tr(status="error:ValueError"))
        path = str(tmp_path / "box.jsonl")
        recs = rec.dump(path, reason="post_drill")
        assert len(recs) == 1
        lines = [json.loads(x) for x in
                 open(path).read().strip().splitlines()]
        assert lines[0] == {"kind": "dump", "reason": "post_drill",
                            "retained": 1}
        assert lines[1]["reason"] == "error"
        assert rec.dumps == [path]
        assert rec.dump_reasons == ["post_drill"]
        assert rec.last_dump == lines

    def test_dump_dir_numbers_files(self, tmp_path):
        rec = FlightRecorder(capacity=8)
        rec.enabled = True
        rec.dump_dir = str(tmp_path)
        rec.dump(reason="a")
        rec.dump(reason="b")
        assert [p.name for p in sorted(tmp_path.iterdir())] == \
            ["flight-0000.jsonl", "flight-0001.jsonl"]


class TestFaultAutodump:
    def test_chaos_battery_every_fault_leaves_a_dump(self, tmp_path):
        """The acceptance drill: arm a battery of fault points; every
        one that fires must leave a black-box JSONL artifact, and the
        follow-up dump must contain the erroring span tree."""
        obs.FLIGHT_RECORDER.enable(capacity=32, sample_rate=1.0,
                                   dump_dir=str(tmp_path))
        battery = ["lsm:merge:before_manifest", "cold:checkpoint:data",
                   "shard:s01:query"]
        for point in battery:
            FAULTS.arm(point)
            with pytest.raises(FaultError):
                with obs.trace("request", tenant="acme"):
                    FAULTS.check(point)
        reasons = obs.FLIGHT_RECORDER.dump_reasons
        for point in battery:
            assert f"fault:{point}" in reasons          # immediate dump
            assert f"fault:{point}:post" in reasons     # after the trace
        files = sorted(tmp_path.iterdir())
        assert len(files) == len(reasons) == 2 * len(battery)
        # the post dump holds the erroring trace itself
        last = [json.loads(x) for x in
                open(files[-1]).read().strip().splitlines()]
        assert last[0]["reason"] == f"fault:{battery[-1]}:post"
        errors = [r for r in last[1:] if r.get("reason") == "error"]
        assert len(errors) == len(battery)
        assert errors[-1]["spans"]["status"] == "error:FaultError"

    def test_listener_survives_faults_reset(self, tmp_path):
        obs.FLIGHT_RECORDER.enable(capacity=8, sample_rate=0.0,
                                   dump_dir=str(tmp_path))
        FAULTS.reset()          # teardown-style reset must NOT unhook
        FAULTS.arm("x:y:z")
        with pytest.raises(FaultError):
            FAULTS.check("x:y:z")
        assert "fault:x:y:z" in obs.FLIGHT_RECORDER.dump_reasons

    def test_disable_unhooks_listener(self):
        obs.FLIGHT_RECORDER.enable(capacity=8)
        obs.FLIGHT_RECORDER.disable()
        FAULTS.arm("x:y:z")
        with pytest.raises(FaultError):
            FAULTS.check("x:y:z")
        assert obs.FLIGHT_RECORDER.dump_reasons == []

    def test_trace_exit_feeds_singleton_only_when_enabled(self):
        with obs.trace("request"):
            pass
        assert obs.FLIGHT_RECORDER.records() == []
        obs.FLIGHT_RECORDER.enable(capacity=8, sample_rate=1.0)
        with obs.trace("request", tenant="acme"):
            pass
        (r,) = obs.FLIGHT_RECORDER.records()
        assert r["reason"] == "sampled"
        assert r["attrs"]["tenant"] == "acme"
