"""Storage-integrity tests (DESIGN.md §16): corruption containment,
quarantine, background scrubbing, and replica-driven repair.

Battery per artifact class (hot segment npz, fp32 sidecar, cold
segment, checkpoint, archive, WAL record): inject bit-rot / torn
writes / zeroed ranges, then assert the store QUARANTINES the artifact
and keeps serving unaffected docs instead of dying; that caches
(checkpoints, archives) fall back losslessly; that the scrubber finds
rot no query has touched; and that ``ShardFabric.repair`` restores
current AND temporal results to oracle equivalence — on live fabrics
and on reopened ones.
"""
import glob
import os

import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.core.wal import WriteAheadLog
from repro.serve.maintenance import StoreMaintenance
from repro.shard import ShardFabric, results_equivalent
from repro.testing.faults import CORRUPT_MODES, FAULTS, corrupt_file

DIM = 32

VOCAB = ["alpha", "bravo", "carbon", "delta", "ember", "fjord",
         "glacier", "harbor", "isotope", "jetty", "kernel", "lagoon"]


@pytest.fixture(autouse=True)
def _reset_faults():
    FAULTS.reset()
    yield
    FAULTS.reset()


def make_stream(n_docs=4, n_versions=3):
    """Deterministic ingest stream with strictly increasing ts."""
    stream, ts = [], 0
    for v in range(n_versions):
        for i in range(n_docs):
            ts += 1_000_000
            text = (f"{VOCAB[i]} {VOCAB[(i + v) % len(VOCAB)]} "
                    f"first chunk of doc {i} version {v}.\n\n"
                    f"{VOCAB[(i + 2 * v + 1) % len(VOCAB)]} second "
                    f"chunk payload {i} {v}.")
            stream.append((f"doc{i}", text, ts))
    return stream


def build_store(root, stream=None, **kw):
    kw.setdefault("cold_checkpoint_interval", 0)
    st = LiveVectorLake(str(root), dim=DIM, **kw)
    for doc, text, ts in (stream or []):
        st.ingest(doc, text, ts=ts)
    return st


def res_key(results):
    return [(r.doc_id, r.position, r.valid_from, round(r.score, 4))
            for r in results]


def cold_seg_files(st):
    return sorted(glob.glob(os.path.join(st.root, "cold", "segments",
                                         "seg-*.npz")))


def hot_seg_files(st):
    return sorted(glob.glob(os.path.join(st.root, "hot_index",
                                         "seg-*.npz")))


# ---------------------------------------------------------------------------
# WAL record CRCs
# ---------------------------------------------------------------------------
class TestWalCrc:
    def _mk(self, tmp_path, n=4):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        for i in range(n):
            t = wal.begin("ingest", {"doc_id": f"d{i}", "i": i})
            wal.mark(t, "COLD_OK")
            wal.mark(t, "COMMIT")
        return wal

    def test_torn_tail_truncated_loudly(self, tmp_path):
        wal = self._mk(tmp_path)
        path = wal._path
        with open(path, "a") as f:
            f.write('{"txn": 99, "state"')       # torn mid-write
        w2 = WriteAheadLog(path)
        assert w2.truncated_records >= 1
        assert w2.state(4) == "COMMIT"
        # REGRESSION: the torn line must be PHYSICALLY gone — records
        # appended after it must survive the NEXT replay
        t = w2.begin("ingest", {"doc_id": "post"})
        w2.mark(t, "COMMIT")
        w3 = WriteAheadLog(path)
        assert w3.state(t) == "COMMIT"
        assert w3.truncated_records == 0

    def test_bad_crc_record_truncates_and_quarantines(self, tmp_path):
        wal = self._mk(tmp_path, n=4)
        path = wal._path
        with open(path) as f:
            lines = f.readlines()
        # mutate a MIDDLE record's body, keeping valid JSON: the crc no
        # longer matches => bit-rot inside a committed record
        bad_i = len(lines) // 2
        lines[bad_i] = lines[bad_i].replace('"state":"', '"state":"X')
        with open(path, "w") as f:
            f.writelines(lines)
        w2 = WriteAheadLog(path)
        # everything from the rotten record on is dropped (loudly)...
        assert w2.truncated_records >= len(lines) - bad_i
        # ...and the discarded tail is quarantined as evidence
        assert w2.quarantine.records()
        assert any(r["artifact"] == "wal_record"
                   for r in w2.quarantine.records())

    def test_live_scrub_self_heals(self, tmp_path):
        wal = self._mk(tmp_path, n=6)
        path = wal._path
        with open(path) as f:
            lines = f.readlines()
        lines[2] = lines[2].replace('"state":"', '"state":"X')
        with open(path, "w") as f:
            f.writelines(lines)
        rep = wal.scrub()
        assert rep["bad"] >= 1
        # the log was rewritten from authoritative RAM state: a fresh
        # replay sees every transaction, zero truncation
        w2 = WriteAheadLog(path)
        assert w2.truncated_records == 0
        assert wal.scrub()["bad"] == 0

    def test_pre_crc_records_replay(self, tmp_path):
        path = str(tmp_path / "wal.jsonl")
        with open(path, "w") as f:     # legacy line without a crc field
            f.write('{"txn": 1, "state": "COMMIT", "ts": 0}\n')
        w = WriteAheadLog(path)
        assert w.state(1) == "COMMIT"
        assert w.truncated_records == 0


# ---------------------------------------------------------------------------
# hot tier: segment npz + fp32 sidecar
# ---------------------------------------------------------------------------
class TestHotCorruption:
    @pytest.mark.parametrize("mode", CORRUPT_MODES)
    def test_quarantine_then_rebuild_from_cold(self, tmp_path, mode):
        st = build_store(tmp_path / "s", make_stream())
        st.hot.index.seal()
        before = res_key(st.query(f"{VOCAB[0]} first chunk", k=6))
        segs = hot_seg_files(st)
        assert segs
        assert corrupt_file(segs[0], mode)
        st2 = build_store(tmp_path / "s")
        # containment: the rotten segment was quarantined, its rows
        # re-derived from cold authority — results identical
        assert res_key(st2.query(f"{VOCAB[0]} first chunk", k=6)) \
            == before
        qdir = os.path.join(st2.root, "hot_index", "quarantine")
        assert os.path.exists(os.path.join(
            qdir, os.path.basename(segs[0])))
        assert not os.path.exists(segs[0])
        # the rebuild doubles as the repair: not degraded
        assert not st2.integrity.degraded()
        assert any(r["artifact"] == "hot_segment" and r["repaired"]
                   for r in st2.hot.index.quarantine.records())

    def test_f32_sidecar_corruption_quantized(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream(), quantized=True)
        st.hot.index.seal()
        before = res_key(st.query(f"{VOCAB[1]} second chunk", k=6))
        sidecars = sorted(glob.glob(os.path.join(
            st.root, "hot_index", "seg-*.f32.npy")))
        assert sidecars
        assert corrupt_file(sidecars[0], "bitflip")
        st2 = build_store(tmp_path / "s")
        assert res_key(st2.query(f"{VOCAB[1]} second chunk", k=6)) \
            == before
        assert st2.hot.index.quarantine.records()

    def test_orphan_sweep_never_deletes_quarantined(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream())
        st.hot.index.seal()
        seg = hot_seg_files(st)[0]
        corrupt_file(seg, "bitflip")
        st2 = build_store(tmp_path / "s")
        qfile = os.path.join(st2.root, "hot_index", "quarantine",
                             os.path.basename(seg))
        assert os.path.exists(qfile)
        # seal + compact cycles re-run the orphan sweep repeatedly: the
        # quarantined evidence must survive every one of them
        for doc, text, ts in make_stream(n_docs=2, n_versions=2):
            st2.ingest(doc + "x", text, ts=ts + 10_000_000)
        st2.hot.index.seal()
        while st2.hot.index.compact_once():
            pass
        assert os.path.exists(qfile)


# ---------------------------------------------------------------------------
# cold tier: segments (data), checkpoints + archives (caches)
# ---------------------------------------------------------------------------
class TestColdCorruption:
    def test_segment_quarantine_keeps_serving_others(self, tmp_path):
        stream = make_stream()
        st = build_store(tmp_path / "s", stream)
        last_ts = stream[-1][2]
        # doc0's FIRST version lives in cold segment 1 alone
        seg = cold_seg_files(st)[0]
        corrupt_file(seg, "bitflip")
        st.temporal.invalidate()
        res = st.query(f"{VOCAB[0]} first chunk", k=16,
                       at=last_ts + 1)
        # the store did NOT die; doc0's rotten rows are out, others serve
        assert res is not None
        assert st.integrity.degraded()
        assert st.integrity.affected_docs() == {"doc0"}
        assert st.cold.quarantine.is_quarantined(os.path.basename(seg))
        others = st.query(f"{VOCAB[1]} first chunk", k=8,
                          at=last_ts + 1)
        assert any(r.doc_id != "doc0" for r in others)

    def test_checkpoint_corruption_falls_back(self, tmp_path):
        stream = make_stream()
        st = build_store(tmp_path / "s", stream)
        st.cold.write_checkpoint()
        last_ts = stream[-1][2]
        st.temporal.invalidate()
        before = res_key(st.query(f"{VOCAB[2]} payload", k=8,
                                  at=last_ts + 1))
        ckpts = glob.glob(os.path.join(st.root, "cold", "_ckpt",
                                       "ckpt-*.npz"))
        assert ckpts
        corrupt_file(ckpts[0], "zero")
        st.temporal.invalidate()
        after = res_key(st.query(f"{VOCAB[2]} payload", k=8,
                                 at=last_ts + 1))
        # a checkpoint is a pure cache: fold falls back, zero data loss
        assert after == before
        assert st.cold.quarantine.is_quarantined(
            os.path.basename(ckpts[0]))
        assert not st.integrity.degraded()

    def test_archive_corruption_falls_back(self, tmp_path):
        stream = make_stream(n_docs=3, n_versions=4)
        st = build_store(tmp_path / "s", stream)
        rep = st.compact_cold(min_run=2)
        arcs = glob.glob(os.path.join(st.root, "cold", "_archive",
                                      "arc-*.npz"))
        assert rep["archived_runs"] >= 1 and arcs
        mid_ts = stream[len(stream) // 2][2]
        st.temporal.invalidate()
        before = res_key(st.query(f"{VOCAB[0]} first chunk", k=8,
                                  at=mid_ts + 1))
        corrupt_file(arcs[0], "truncate")
        st.temporal.invalidate()
        after = res_key(st.query(f"{VOCAB[0]} first chunk", k=8,
                                 at=mid_ts + 1))
        # archives are overlays over retained per-commit segments: the
        # fold retries without the rotten archive, byte-equal results
        assert after == before
        assert st.cold.quarantine.is_quarantined(
            os.path.basename(arcs[0]))
        assert not st.integrity.degraded()


# ---------------------------------------------------------------------------
# deterministic injection through FAULTS.corrupt / mutate
# ---------------------------------------------------------------------------
class TestCorruptionInjection:
    @pytest.mark.parametrize("mode", CORRUPT_MODES)
    def test_cold_segment_injection(self, tmp_path, mode):
        FAULTS.corrupt("cold:segment:file", mode=mode, nth=2)
        stream = make_stream(n_docs=3, n_versions=2)
        st = build_store(tmp_path / "s", stream)
        assert FAULTS.fired("cold:segment:file") == 1
        FAULTS.reset()
        # the write path reported success; the rot is only found when
        # the fold reads the segment back
        st.temporal.invalidate()
        st.query("anything at all", k=4, at=stream[-1][2] + 1)
        assert st.integrity.degraded()
        assert len(st.cold.quarantine.pending_data_loss()) == 1

    def test_wal_record_injection(self, tmp_path):
        FAULTS.corrupt("wal:record", mode="bitflip", nth=3)
        stream = make_stream(n_docs=2, n_versions=2)
        st = build_store(tmp_path / "s", stream)
        FAULTS.reset()
        st2 = build_store(tmp_path / "s")
        # replay truncated at the rotten record and recovery resumed
        # loudly — the store still serves
        assert st2.wal.truncated_records >= 1
        assert st2.query(f"{VOCAB[0]} first", k=4)

    def test_hot_segment_injection(self, tmp_path):
        FAULTS.corrupt("hot:segment:file", mode="zero")
        st = build_store(tmp_path / "s", make_stream(n_docs=3))
        st.hot.index.seal()
        assert FAULTS.fired("hot:segment:file") == 1
        FAULTS.reset()
        st2 = build_store(tmp_path / "s")
        assert st2.hot.index.quarantine.records()
        assert len(st2.query(f"{VOCAB[0]} first chunk", k=4)) > 0


# ---------------------------------------------------------------------------
# background scrubber
# ---------------------------------------------------------------------------
class TestScrubber:
    def test_clean_store_scrubs_clean(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream())
        st.hot.index.seal()
        st.cold.write_checkpoint()
        rep = st.scrubber.scrub_full()
        assert rep["corrupt"] == 0 and rep["checked"] > 0
        state = st.scrubber.state()
        assert state["passes"] >= 1 and state["corrupt"] == 0
        assert os.path.exists(os.path.join(st.root, "SCRUB.json"))

    def test_detects_rot_no_query_ever_read(self, tmp_path):
        stream = make_stream()
        st = build_store(tmp_path / "s", stream)
        st.hot.index.seal()
        seg = cold_seg_files(st)[1]
        corrupt_file(seg, "bitflip")
        # NO query touches the rotten segment — the scrubber finds it
        rep = st.scrubber.scrub_full()
        assert rep["corrupt"] == 1
        assert st.cold.quarantine.is_quarantined(os.path.basename(seg))
        assert st.integrity.degraded()

    def test_cursor_survives_reopen(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream())
        st.scrubber.scrub_once(budget=2)
        cur = st.scrubber.state()["cursor"]
        assert cur
        st2 = build_store(tmp_path / "s")
        assert st2.scrubber.state()["cursor"] == cur
        st2.scrubber.scrub_once(budget=2)
        assert st2.scrubber.state()["cursor"] != cur

    def test_scrub_heals_hot_inline(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream())
        st.hot.index.seal()
        before = res_key(st.query(f"{VOCAB[0]} first chunk", k=6))
        seg = hot_seg_files(st)[0]
        corrupt_file(seg, "truncate")
        rep = st.scrubber.scrub_full()
        assert rep["corrupt"] >= 1
        # hot rot self-heals in place: quarantine + rebuild from cold
        assert res_key(st.query(f"{VOCAB[0]} first chunk", k=6)) \
            == before
        assert not st.integrity.degraded()

    def test_maintenance_scrub_job(self, tmp_path):
        st = build_store(tmp_path / "s", make_stream(n_docs=2))
        sm = StoreMaintenance(st, scrub_interval_s=1e-9)
        sm.start()
        try:
            st.ingest("docz", "fresh words arrive here. second chunk.",
                      ts=10**9)
            assert sm.drain(timeout=5.0)
            assert os.path.exists(os.path.join(st.root, "SCRUB.json"))
            assert sm.scrub_now()["corrupt"] == 0
        finally:
            sm.stop()


# ---------------------------------------------------------------------------
# replica-driven repair (the tentpole drill)
# ---------------------------------------------------------------------------
def drive(target, stream):
    for doc, text, ts in stream:
        target.ingest(doc, text, ts=ts)


def check_parity(oracle, fab, queries, k=5, **kw):
    o = oracle.query_batch(queries, k=k, **kw)
    oe = oracle.query_batch(queries, k=4 * k, **kw)
    f = fab.query_batch(queries, k=k, **kw)
    for qi in range(len(queries)):
        assert results_equivalent(o[qi], f[qi], oe[qi]), (
            kw, res_key(o[qi]), res_key(f[qi]))


def mk_pair(tmp_path, stream, replicas=2, shards=2):
    oracle = build_store(tmp_path / "oracle", stream,
                         hot_capacity=4096)
    # checkpoints off: a checkpoint is a fold overlay that can mask a
    # quarantined segment's rows (lossless fallback — good in prod,
    # but these drills need REAL data loss to exercise replica repair)
    fab = ShardFabric(str(tmp_path / "fab"), n_shards=shards,
                      replicas=replicas, dim=DIM, hot_capacity=4096,
                      cold_checkpoint_interval=0)
    drive(fab, stream)
    return oracle, fab


QUERIES = [f"{VOCAB[0]} first chunk", f"{VOCAB[1]} second chunk",
           f"{VOCAB[3]} payload", f"{VOCAB[5]} version"]


class TestFabricRepair:
    def test_repair_restores_oracle_equivalence(self, tmp_path):
        stream = make_stream(n_docs=6, n_versions=3)
        oracle, fab = mk_pair(tmp_path, stream)
        mid_ts = stream[len(stream) // 2][2]
        last_ts = stream[-1][2]
        victim = fab.lake("s00").store
        seg = cold_seg_files(victim)[0]
        corrupt_file(seg, "bitflip")
        # scrubber detects it (no query read the segment)
        assert victim.scrubber.scrub_full()["corrupt"] == 1
        assert victim.integrity.degraded()
        # degraded serving: the gather is stamped, nothing crashes
        fab.query_batch(QUERIES, k=5, at=last_ts + 1)
        lg = fab.planner.last_gather
        assert lg["degraded"] and lg["integrity_degraded"] == ["s00"]
        # replica-driven repair: the other owner replays the history
        rep = fab.repair()
        assert rep["docs_repaired"] >= 1
        assert rep["rows_restored"] >= 1
        assert not rep["unrepairable"]
        assert not victim.integrity.degraded()
        # current + temporal + window results all oracle-equivalent
        check_parity(oracle, fab, QUERIES, k=5)
        check_parity(oracle, fab, QUERIES, k=5, at=mid_ts + 1)
        check_parity(oracle, fab, QUERIES, k=5, at=last_ts + 1)
        check_parity(oracle, fab, QUERIES, k=5,
                     window=(0, last_ts + 1))
        fab.query_batch(QUERIES[:1], k=5)
        assert fab.planner.last_gather["integrity_degraded"] == []

    def test_repair_on_reopened_fabric(self, tmp_path):
        stream = make_stream(n_docs=4, n_versions=3)
        oracle, fab = mk_pair(tmp_path, stream)
        last_ts = stream[-1][2]
        victim = fab.lake("s01").store
        seg = cold_seg_files(victim)[-1]
        corrupt_file(seg, "zero")
        assert victim.scrubber.scrub_full()["corrupt"] == 1
        del fab, victim
        # quarantine state is durable: a fresh fabric is still degraded
        fab2 = ShardFabric(str(tmp_path / "fab"))
        assert fab2.lake("s01").store.integrity.degraded()
        rep = fab2.repair()
        assert rep["docs_repaired"] >= 1
        assert not fab2.lake("s01").store.integrity.degraded()
        check_parity(oracle, fab2, QUERIES, k=5)
        check_parity(oracle, fab2, QUERIES, k=5, at=last_ts + 1)

    def test_health_surfaces_integrity_and_scrub(self, tmp_path):
        stream = make_stream(n_docs=3, n_versions=2)
        _, fab = mk_pair(tmp_path, stream)
        victim = fab.lake("s00").store
        corrupt_file(cold_seg_files(victim)[0], "bitflip")
        victim.scrubber.scrub_full()
        h = fab.health()
        assert h["integrity"]["s00"]["degraded"]
        assert h["integrity"]["s00"]["data_loss_pending"] == 1
        assert h["scrub"]["s00"]["passes"] >= 1
        fab.repair()
        assert not fab.health()["integrity"]["s00"]["degraded"]

    def test_anti_entropy_finds_and_merges_divergence(self, tmp_path):
        stream = make_stream(n_docs=4, n_versions=2)
        oracle, fab = mk_pair(tmp_path, stream)
        victim = fab.lake("s00").store
        seg = cold_seg_files(victim)[0]
        corrupt_file(seg, "bitflip")
        victim.scrubber.scrub_full()
        # digests now differ between the replicas for the affected doc
        ae = fab.run_anti_entropy()
        assert ae["diverged"] >= 1 and ae["repaired"]
        # after the bidirectional merge all replicas agree again
        ae2 = fab.run_anti_entropy()
        assert ae2["diverged"] == 0
        victim.integrity.cold.mark_repaired()
        check_parity(oracle, fab, QUERIES, k=5)

    def test_double_corruption_hot_and_cold(self, tmp_path):
        """The CI drill shape: bit-rot in a hot segment AND a cold
        segment of the same shard; quarantine both, keep serving, one
        repair() restores everything."""
        stream = make_stream(n_docs=5, n_versions=3)
        oracle, fab = mk_pair(tmp_path, stream)
        last_ts = stream[-1][2]
        victim = fab.lake("s00").store
        victim.hot.index.seal()
        corrupt_file(hot_seg_files(victim)[0], "bitflip")
        corrupt_file(cold_seg_files(victim)[2], "truncate")
        rep = victim.scrubber.scrub_full()
        assert rep["corrupt"] == 2
        # both quarantined; fabric still answers
        assert fab.query_batch(QUERIES[:2], k=5)
        r = fab.repair()
        assert not r["unrepairable"]
        assert not victim.integrity.degraded()
        check_parity(oracle, fab, QUERIES, k=5)
        check_parity(oracle, fab, QUERIES, k=5, at=last_ts + 1)

    def test_repair_is_idempotent(self, tmp_path):
        stream = make_stream(n_docs=3, n_versions=2)
        oracle, fab = mk_pair(tmp_path, stream)
        victim = fab.lake("s00").store
        corrupt_file(cold_seg_files(victim)[0], "bitflip")
        victim.scrubber.scrub_full()
        r1 = fab.repair()
        assert r1["rows_restored"] >= 1
        r2 = fab.repair()
        assert r2["rows_restored"] == 0 and r2["docs_repaired"] == 0
        check_parity(oracle, fab, QUERIES, k=5)
