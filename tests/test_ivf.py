"""IVF index: recall vs exact scan, nprobe monotonicity, scan fraction."""
import numpy as np
import pytest

from repro.core.ivf import IVFIndex


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    # clustered corpus: 16 clusters in 64-d
    centers = rng.standard_normal((16, 64)).astype(np.float32)
    pts = np.concatenate([
        c + 0.15 * rng.standard_normal((200, 64)).astype(np.float32)
        for c in centers])
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    return pts


def test_exact_when_nprobe_full(corpus):
    idx = IVFIndex(n_centroids=16)
    idx.build(corpus)
    assert idx.recall_at_k(corpus[:32], k=10, nprobe=16) == 1.0


def test_recall_improves_with_nprobe(corpus):
    idx = IVFIndex(n_centroids=32)
    idx.build(corpus)
    q = corpus[100:140]
    recalls = [idx.recall_at_k(q, k=10, nprobe=p) for p in (1, 4, 16, 32)]
    assert recalls[-1] == 1.0
    assert all(b >= a - 1e-9 for a, b in zip(recalls, recalls[1:]))
    assert recalls[1] >= 0.8             # clustered data: few probes win


def test_sublinear_scan_fraction(corpus):
    idx = IVFIndex(n_centroids=32)
    idx.build(corpus)
    _, _, stats = idx.search(corpus[:8], k=5, nprobe=4)
    assert stats.fraction_scanned < 0.4


def test_self_query_top1(corpus):
    idx = IVFIndex(n_centroids=16)
    idx.build(corpus)
    s, i, _ = idx.search(corpus[7:8], k=1, nprobe=4)
    assert int(i[0, 0]) == 7
