"""flash_attention + flash_decode kernel validation vs jnp oracles
(interpret=True on CPU), swept over shapes, dtypes, GQA groups."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref


def _rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("b,h,kv,sq,skv,d,causal", [
    (1, 4, 4, 128, 128, 64, True),      # MHA causal
    (2, 8, 2, 128, 256, 64, True),      # GQA group=4, prefill vs longer kv
    (1, 4, 1, 256, 256, 32, False),     # MQA bidirectional
    (1, 2, 2, 128, 384, 128, True),     # d=128 MXU-width
])
def test_flash_attention_matches_ref(b, h, kv, sq, skv, d, causal):
    q = _rand((b, h, sq, d), 1)
    k = _rand((b, kv, skv, d), 2)
    v = _rand((b, kv, skv, d), 3)
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = _rand((1, 4, 128, 64), 1).astype(jnp.bfloat16)
    k = _rand((1, 2, 128, 64), 2).astype(jnp.bfloat16)
    v = _rand((1, 2, 128, 64), 3).astype(jnp.bfloat16)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, mode="interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_block_sweep():
    q, k, v = (_rand((1, 2, 256, 64), i) for i in range(3))
    ref = None
    for bq, bk in [(64, 64), (128, 256), (256, 128)]:
        out = np.asarray(flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True,
            bq=bq, bk=bk, mode="interpret"))
        if ref is None:
            ref = out
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,kv,s,d,bs,cache_len", [
    (1, 4, 4, 512, 64, 256, None),       # full cache
    (2, 8, 2, 1024, 64, 256, 700),       # partial cache, GQA
    (1, 4, 1, 512, 128, 512, 512),       # MQA single split
    (1, 2, 2, 2048, 32, 256, 1),         # single valid token
])
def test_flash_decode_matches_ref(b, h, kv, s, d, bs, cache_len):
    q = _rand((b, h, d), 1)
    kc = _rand((b, kv, s, d), 2)
    vc = _rand((b, kv, s, d), 3)
    cl = s if cache_len is None else cache_len
    ref = decode_attention_ref(jnp.asarray(q), jnp.asarray(kc),
                               jnp.asarray(vc),
                               cache_len=jnp.full((b,), cl, jnp.int32))
    out = flash_decode(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                       cache_len=cl, bs=bs, mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_split_invariance():
    """Split count must not change the result (merge correctness)."""
    q, kc, vc = _rand((1, 4, 64), 1), _rand((1, 4, 1024, 64), 2), \
        _rand((1, 4, 1024, 64), 3)
    outs = [np.asarray(flash_decode(jnp.asarray(q), jnp.asarray(kc),
                                    jnp.asarray(vc), cache_len=900, bs=bs,
                                    mode="interpret"))
            for bs in (128, 256, 1024)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_decode_matches_flash_attention_last_token():
    """Consistency across kernels: decode(q_last) == attention last row."""
    b, h, s, d = 1, 2, 256, 64
    k = _rand((b, h, s, d), 5)
    v = _rand((b, h, s, d), 6)
    q_full = _rand((b, h, s, d), 7)
    full = np.asarray(flash_attention(
        jnp.asarray(q_full), jnp.asarray(k), jnp.asarray(v), causal=True,
        mode="interpret"))
    dec = np.asarray(flash_decode(
        jnp.asarray(q_full[:, :, -1]), jnp.asarray(k), jnp.asarray(v),
        cache_len=s, bs=128, mode="interpret"))
    np.testing.assert_allclose(dec, full[:, :, -1], rtol=2e-5, atol=2e-5)
