"""EmbeddingBag kernel vs oracle, swept over shapes/dtypes/combiners."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _setup(v, d, b, bag, seed=0, pad_frac=0.3, dtype=np.float32):
    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(dtype)
    idx = rng.integers(0, v, (b, bag)).astype(np.int32)
    idx = np.where(rng.random((b, bag)) < pad_frac, -1, idx)
    w = rng.random((b, bag)).astype(np.float32)
    return table, idx, w


@pytest.mark.parametrize("v,d,b,bag,combiner", [
    (1000, 64, 8, 16, "sum"),
    (5000, 128, 4, 8, "mean"),
    (128, 32, 16, 4, "sum"),
    (10000, 16, 2, 32, "mean"),
])
def test_embedding_bag_matches_ref(v, d, b, bag, combiner):
    table, idx, w = _setup(v, d, b, bag)
    ref = embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx),
                            jnp.asarray(w), combiner)
    out = embedding_bag(jnp.asarray(table), idx, w, combiner,
                        mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_all_padding_row():
    table, idx, w = _setup(100, 16, 4, 8)
    idx[2] = -1
    out = np.asarray(embedding_bag(jnp.asarray(table), idx, w, "sum",
                                   mode="interpret"))
    np.testing.assert_allclose(out[2], 0.0, atol=1e-7)
    out_m = np.asarray(embedding_bag(jnp.asarray(table), idx, w, "mean",
                                     mode="interpret"))
    assert np.all(np.isfinite(out_m))


def test_default_weights():
    table, idx, _ = _setup(100, 16, 4, 8)
    a = np.asarray(embedding_bag(jnp.asarray(table), idx, None, "sum",
                                 mode="interpret"))
    ones = np.ones(idx.shape, np.float32)
    b = np.asarray(embedding_bag(jnp.asarray(table), idx, ones, "sum",
                                 mode="interpret"))
    np.testing.assert_allclose(a, b)


def test_bf16_table():
    table, idx, w = _setup(500, 64, 4, 8)
    t16 = jnp.asarray(table, jnp.bfloat16)
    ref = embedding_bag_ref(t16, jnp.asarray(idx), jnp.asarray(w), "sum")
    out = embedding_bag(t16, idx, w, "sum", mode="interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ref_mode_dispatch():
    table, idx, w = _setup(200, 32, 4, 8)
    a = np.asarray(embedding_bag(jnp.asarray(table), idx, w, "sum",
                                 mode="ref"))
    b = np.asarray(embedding_bag(jnp.asarray(table), idx, w, "sum",
                                 mode="interpret"))
    np.testing.assert_allclose(a, b, rtol=1e-6)
