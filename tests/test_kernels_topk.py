"""Kernel validation: fused top-k search + temporal masked scoring (fp32
and the int8 asymmetric q8 variants) vs the pure oracles, interpret=True
on CPU, swept over shapes/dtypes."""
import numpy as np
import pytest

from repro.core.types import VALID_TO_OPEN
from repro.index.quant import (data_scale, fixed_scale, quantize_rows,
                               rescore_topk)
from repro.kernels.topk_search.ops import topk_search, topk_search_q8
from repro.kernels.topk_search.ref import topk_search_ref
from repro.kernels.temporal_mask_score.ops import (temporal_topk,
                                                   temporal_window_topk_q8)
from repro.kernels.temporal_mask_score.ref import temporal_topk_ref

# the q8 kernel modes exercised on CPU ("pallas" needs a TPU; "host" is
# the integer-GEMM serving path, "interpret" the lowered Pallas body)
Q8_MODES = ("ref", "interpret", "host")


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


@pytest.mark.parametrize("nq,n,d,k,bn", [
    (1, 256, 128, 5, 128),
    (4, 1000, 384, 10, 256),     # n not a multiple of bn -> padding path
    (8, 512, 64, 3, 512),
    (2, 130, 384, 7, 128),
    (3, 64, 256, 64, 128),       # k == n
])
def test_topk_matches_ref(nq, n, d, k, bn):
    q, c = _rand((nq, d), 1), _rand((n, d), 2)
    mask = np.random.default_rng(3).random(n) > 0.3
    s_ref, i_ref = topk_search_ref(q, c, mask, min(k, n))
    s_k, i_k = topk_search(q, c, mask, k, bn=bn, mode="interpret")
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    # indices may differ on exact score ties; verify score-equivalence at
    # every FINITE slot (indices of -inf slots are meaningless)
    finite = np.isfinite(np.asarray(s_ref))
    s_at_k = np.einsum("qd,qkd->qk", q, c[np.asarray(i_k) % n])
    s_at_r = np.einsum("qd,qkd->qk", q, c[np.asarray(i_ref) % n])
    np.testing.assert_allclose(s_at_k[finite], s_at_r[finite],
                               rtol=1e-5, atol=1e-5)


def test_topk_all_masked_returns_neg_inf():
    q, c = _rand((2, 64)), _rand((100, 64))
    s, i = topk_search(q, c, np.zeros(100, bool), 5, mode="interpret")
    assert np.all(np.isneginf(np.asarray(s)))


def test_topk_ref_mode_matches_interpret():
    q, c = _rand((3, 384), 5), _rand((700, 384), 6)
    mask = np.ones(700, bool)
    s_r, _ = topk_search(q, c, mask, 9, mode="ref")
    s_i, _ = topk_search(q, c, mask, 9, mode="interpret")
    np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_i),
                               rtol=1e-5, atol=1e-5)


class TestQ8TopkKernel:
    """ISSUE 5: edge parity for the int8 asymmetric top-k kernel across
    ref / interpret / host modes, plus pool+rescore exactness."""

    @pytest.mark.parametrize("nq,n,d,k,bn", [
        (1, 256, 128, 5, 128),
        (4, 1000, 384, 10, 256),     # n not a multiple of bn -> padding
        (2, 130, 384, 7, 128),
        (3, 64, 256, 64, 128),       # k == n
    ])
    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_pool_covers_exact_topk(self, nq, n, d, k, bn, mode):
        """With a 4x over-fetched pool and exact fp32 rescore, the q8
        path must recover the fp32 oracle's top-k row set (scores
        fp32-exact by construction)."""
        q, c = _rand((nq, d), 1), _rand((n, d), 2)
        mask = np.random.default_rng(3).random(n) > 0.3
        c8 = quantize_rows(c, data_scale(c))
        kp = min(4 * k, n)
        _, pool = topk_search_q8(q, c8, data_scale(c), mask, kp,
                                 bn=bn, mode=mode)
        s_q, i_q = rescore_topk(q, np.asarray(pool), c, min(k, n))
        s_ref, i_ref = topk_search_ref(q, c, mask, min(k, n))
        s_ref = np.asarray(s_ref)
        fin = np.isfinite(s_ref)
        np.testing.assert_allclose(s_q[fin], s_ref[fin],
                                   rtol=1e-5, atol=1e-5)
        for qi in range(nq):
            want = set(np.asarray(i_ref)[qi][fin[qi]].tolist())
            got = set(i_q[qi][np.isfinite(s_q[qi])].tolist())
            assert got == want, mode

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_all_masked_returns_neg_inf_and_minus_one(self, mode):
        q, c = _rand((2, 64)), _rand((100, 64))
        c8 = quantize_rows(c, fixed_scale(64))
        s, i = topk_search_q8(q, c8, fixed_scale(64),
                              np.zeros(100, bool), 5, mode=mode)
        assert np.all(np.isneginf(np.asarray(s))), mode
        # the -1 contract: a rescore can never resurrect a masked row
        assert np.all(np.asarray(i) == -1), mode

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_k_exceeds_valid_candidates(self, mode):
        q, c = _rand((2, 64), 1), _rand((64, 64), 2)
        mask = np.zeros(64, bool)
        mask[:3] = True
        c8 = quantize_rows(c, fixed_scale(64))
        s, i = topk_search_q8(q, c8, fixed_scale(64), mask, 10, mode=mode)
        s, i = np.asarray(s), np.asarray(i)
        for qi in range(2):
            fin = np.isfinite(s[qi])
            assert fin.sum() == 3, mode
            assert set(i[qi][fin]) == {0, 1, 2}, mode
            assert np.all(i[qi][~fin] == -1), mode

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_empty_corpus(self, mode):
        c8 = np.zeros((0, 32), np.int8)
        s, i = topk_search_q8(_rand((2, 32)), c8, fixed_scale(32),
                              np.zeros(0, bool), 5, mode=mode)
        assert np.asarray(s).shape == (2, 0)

    def test_host_numpy_fallback_matches_torch_path(self, monkeypatch):
        """The blocked cast+sgemm fallback (torch absent) must select
        the same pool membership as the integer-GEMM path after exact
        rescore — torch is an accelerator, never a dependency."""
        from repro.kernels import qscan
        q, c = _rand((3, 96), 11), _rand((400, 96), 12)
        scale = data_scale(c)
        c8 = quantize_rows(c, scale)
        mask = np.ones(400, bool)
        _, pool_fast = topk_search_q8(q, c8, scale, mask, 32, mode="host")
        monkeypatch.setattr(qscan, "_TORCH", None)
        assert not qscan.have_int8_host()
        _, pool_slow = topk_search_q8(q, c8, scale, mask, 32, mode="host")
        _, i_fast = rescore_topk(q, np.asarray(pool_fast), c, 8)
        _, i_slow = rescore_topk(q, np.asarray(pool_slow), c, 8)
        np.testing.assert_array_equal(i_fast, i_slow)

    def test_modes_agree_on_pool_membership(self):
        """ref vs interpret must be score-identical (same math); the
        host path (query also quantized) may perturb pool ORDER but the
        exact rescore of each pool must agree on the final top-k."""
        q, c = _rand((3, 128), 5), _rand((700, 128), 6)
        scale = data_scale(c)
        c8 = quantize_rows(c, scale)
        mask = np.ones(700, bool)
        s_r, i_r = topk_search_q8(q, c8, scale, mask, 40, mode="ref")
        s_i, i_i = topk_search_q8(q, c8, scale, mask, 40, mode="interpret")
        np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_i),
                                   rtol=1e-5, atol=1e-5)
        for mode in Q8_MODES:
            _, pool = topk_search_q8(q, c8, scale, mask, 40, mode=mode)
            _, i_k = rescore_topk(q, np.asarray(pool), c, 10)
            _, pool_r = topk_search_q8(q, c8, scale, mask, 40, mode="ref")
            _, i_ref = rescore_topk(q, np.asarray(pool_r), c, 10)
            np.testing.assert_array_equal(i_k, i_ref, err_msg=mode)


class TestQ8TemporalKernel:
    """ISSUE 5: edge parity for the int8 temporal validity-masked kernel
    — the leakage guard must be byte-for-byte as strict as fp32."""

    def _corpus(self, n, d=64, seed=0):
        rng = np.random.default_rng(seed)
        c = _rand((n, d), seed)
        base = 1_700_000_000_000_000
        vf = base + rng.integers(0, 10**6, n).astype(np.int64)
        vt = np.where(rng.random(n) < 0.5, VALID_TO_OPEN,
                      vf + rng.integers(1, 10**6, n)).astype(np.int64)
        return c, vf, vt, base

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_windows_match_fp32_oracle_after_rescore(self, mode):
        from repro.kernels.temporal_mask_score.ref import (
            temporal_window_topk_ref)
        c, vf, vt, base = self._corpus(300, seed=7)
        q = _rand((4, 64), 8)
        scale = fixed_scale(64)
        c8 = quantize_rows(c, scale)
        t0s = np.array([vf.min(), vf.min() + 500_000,
                        vf.max(), vf.min() - 10], np.int64)
        t1s = t0s + np.array([1, 300_000, 10**9, 5], np.int64)
        s_ref, i_ref = temporal_window_topk_ref(q, c, vf, vt, t0s, t1s, 6)
        _, pool = temporal_window_topk_q8(q, c8, scale, vf, vt, t0s, t1s,
                                          24, bn=128, mode=mode)
        s_q, i_q = rescore_topk(q, np.asarray(pool), c, 6)
        fin = np.isfinite(s_ref)
        np.testing.assert_allclose(s_q[fin], s_ref[fin],
                                   rtol=1e-5, atol=1e-5)
        # every returned row must overlap its OWN query's window
        for qi in range(4):
            for j in i_q[qi][np.isfinite(s_q[qi])]:
                assert vf[j] < t1s[qi] and t0s[qi] < vt[j], mode

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_all_rows_out_of_window(self, mode):
        c, vf, vt, _ = self._corpus(200)
        scale = fixed_scale(64)
        c8 = quantize_rows(c, scale)
        ts = int(vf.min()) - 1
        b = np.full(3, ts, np.int64)
        s, i = temporal_window_topk_q8(_rand((3, 64), 1), c8, scale,
                                       vf, vt, b, b + 1, 7, mode=mode)
        assert np.all(np.isneginf(np.asarray(s))), mode
        assert np.all(np.asarray(i) == -1), mode

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_k_exceeds_valid(self, mode):
        c, vf, vt, _ = self._corpus(64)
        ts = int(vf.min())
        vf = vf.copy(); vt = vt.copy()
        vf[:] = ts + 1
        vf[:3] = ts
        vt[:3] = VALID_TO_OPEN
        scale = fixed_scale(64)
        b = np.full(2, ts, np.int64)
        s, i = temporal_window_topk_q8(_rand((2, 64), 2),
                                       quantize_rows(c, scale), scale,
                                       vf, vt, b, b + 1, 10, mode=mode)
        s, i = np.asarray(s), np.asarray(i)
        for qi in range(2):
            fin = np.isfinite(s[qi])
            assert fin.sum() == 3, mode
            assert set(i[qi][fin]) == {0, 1, 2}, mode

    @pytest.mark.parametrize("n", [1, 127, 129, 513])
    @pytest.mark.parametrize("mode", ("interpret",))
    def test_non_multiple_of_block_rows(self, n, mode):
        """Padding path: padded int8 rows carry an empty validity
        interval and can never rank."""
        c, vf, vt, _ = self._corpus(n, seed=n)
        scale = fixed_scale(64)
        ts = int(np.median(vf))
        b = np.full(2, ts, np.int64)
        s, i = temporal_window_topk_q8(_rand((2, 64), 4),
                                       quantize_rows(c, scale), scale,
                                       vf, vt, b, b + 1, 5, bn=128,
                                       mode=mode)
        fin = np.isfinite(np.asarray(s))
        assert np.all(np.asarray(i)[fin] < n)         # no padded index
        assert np.all(np.asarray(i)[~fin] == -1)

    @pytest.mark.parametrize("mode", Q8_MODES)
    def test_empty_history(self, mode):
        c8 = np.zeros((0, 32), np.int8)
        empty = np.zeros(0, np.int64)
        s, i = temporal_window_topk_q8(_rand((2, 32), 3), c8,
                                       fixed_scale(32), empty, empty,
                                       np.zeros(2, np.int64),
                                       np.ones(2, np.int64), 5, mode=mode)
        assert np.asarray(s).shape == (2, 0)


class TestTemporalKernel:
    def _setup(self, n=600, d=384, seed=0):
        rng = np.random.default_rng(seed)
        c = _rand((n, d), seed)
        base = 1_700_000_000_000_000          # realistic unix micros
        vf = base + rng.integers(0, 10**9, n).astype(np.int64)
        vt = np.where(rng.random(n) < 0.5, VALID_TO_OPEN,
                      vf + rng.integers(1, 10**9, n)).astype(np.int64)
        return c, vf, vt, base

    @pytest.mark.parametrize("k,bn,offset", [(5, 128, 5 * 10**8),
                                             (10, 256, 0),
                                             (3, 512, 2 * 10**9)])
    def test_matches_ref(self, k, bn, offset):
        c, vf, vt, base = self._setup()
        q = _rand((2, 384), 9)
        ts = base + offset
        s_ref, i_ref = temporal_topk_ref(q, c, vf, vt, ts, k)
        s_k, i_k = temporal_topk(q, c, vf, vt, ts, k, bn=bn, mode="interpret")
        np.testing.assert_allclose(np.asarray(s_k), s_ref, rtol=1e-5, atol=1e-5)

    def test_no_leakage_microsecond_boundaries(self):
        """Exactness at the validity boundary: ts == valid_from is valid,
        ts == valid_to is NOT (half-open interval), at 1us resolution."""
        d = 64
        c = _rand((4, d), 3)
        vf = np.array([100, 200, 200, 300], np.int64) + 1_700_000_000_000_000
        vt = np.array([200, 300, 201, VALID_TO_OPEN], np.int64)
        vt[:3] += 1_700_000_000_000_000 - np.int64(1_700_000_000_000_000)
        vt = np.array([vf[0] + 100, vf[1] + 100, vf[2] + 1, VALID_TO_OPEN],
                      np.int64)
        q = _rand((1, d), 4)
        for mode in ("ref", "interpret"):
            s, i = temporal_topk(q, c, vf, vt, int(vf[1]), 4, mode=mode)
            s = np.asarray(s)[0]
            i = np.asarray(i)[0]
            valid_rows = {j for j in range(4)
                          if vf[j] <= vf[1] < vt[j]}
            got = {int(i[j]) for j in range(4) if np.isfinite(s[j])}
            assert got == valid_rows, mode

    def test_future_chunks_never_returned(self):
        c, vf, vt, base = self._setup(300)
        q = _rand((1, 384), 11)
        ts = int(np.quantile(vf.astype(np.float64), 0.2))
        for mode in ("ref", "interpret"):
            s, i = temporal_topk(q, c, vf, vt, ts, 20, mode=mode)
            i = np.asarray(i)[0][np.isfinite(np.asarray(s)[0])]
            assert np.all(vf[i] <= ts), mode
            assert np.all(ts < vt[i]), mode


class TestTemporalKernelEdgeCases:
    """ISSUE 3 satellite: kernel parity vs ref.py on the degenerate
    shapes the full-history fused path can hit in production."""

    def _corpus(self, n, d=64, seed=0):
        rng = np.random.default_rng(seed)
        c = _rand((n, d), seed)
        base = 1_700_000_000_000_000
        vf = base + rng.integers(0, 10**6, n).astype(np.int64)
        vt = np.where(rng.random(n) < 0.5, VALID_TO_OPEN,
                      vf + rng.integers(1, 10**6, n)).astype(np.int64)
        return c, vf, vt, base

    def test_all_rows_masked(self):
        """Every row invalid at ts: all slots -inf, no index leaks."""
        c, vf, vt, base = self._corpus(200)
        ts = int(vf.min()) - 1                # before any validity starts
        for mode in ("ref", "interpret"):
            s, i = temporal_topk(_rand((3, 64), 1), c, vf, vt, ts, 7,
                                 mode=mode)
            assert np.all(np.isneginf(np.asarray(s))), mode

    def test_k_exceeds_valid_candidates(self):
        """k > number of valid rows: finite slots carry exactly the valid
        rows, the rest are -inf, in both modes."""
        c, vf, vt, _ = self._corpus(64)
        # make exactly 3 rows valid at ts
        ts = int(vf.min())
        vf = vf.copy(); vt = vt.copy()
        vf[:] = ts + 1
        vf[:3] = ts
        vt[:3] = VALID_TO_OPEN
        for mode in ("ref", "interpret"):
            s, i = temporal_topk(_rand((2, 64), 2), c, vf, vt, ts, 10,
                                 mode=mode)
            s, i = np.asarray(s), np.asarray(i)
            for qi in range(2):
                fin = np.isfinite(s[qi])
                assert fin.sum() == 3, mode
                assert set(i[qi][fin]) == {0, 1, 2}, mode

    def test_empty_history(self):
        """N == 0 corpus: empty result block, no kernel dispatch crash."""
        c = np.zeros((0, 32), np.float32)
        empty = np.zeros(0, np.int64)
        for mode in (None, "ref"):
            s, i = temporal_topk(_rand((2, 32), 3), c, empty, empty,
                                 1000, 5, mode=mode)
            assert np.asarray(s).shape == (2, 0)
            assert np.asarray(i).shape == (2, 0)

    @pytest.mark.parametrize("n", [1, 127, 129, 500, 513])
    def test_non_multiple_of_block_rows(self, n):
        """Row counts that don't divide the block size exercise the
        padding path; padded rows must never rank (empty validity)."""
        c, vf, vt, base = self._corpus(n, seed=n)
        ts = int(np.median(vf))
        q = _rand((2, 64), 4)
        s_ref, i_ref = temporal_topk_ref(q, c, vf, vt, ts, min(5, n))
        s_k, i_k = temporal_topk(q, c, vf, vt, ts, 5, bn=128,
                                 mode="interpret")
        np.testing.assert_allclose(np.asarray(s_k), s_ref,
                                   rtol=1e-5, atol=1e-5)
        fin = np.isfinite(np.asarray(s_k))
        assert np.all(np.asarray(i_k)[fin] < n)       # no padded index

    def test_per_query_windows_match_ref(self):
        """The window kernel's PER-QUERY bounds: each query row gets its
        own overlap mask inside one dispatch."""
        from repro.kernels.temporal_mask_score.ops import temporal_window_topk
        from repro.kernels.temporal_mask_score.ref import (
            temporal_window_topk_ref)
        c, vf, vt, base = self._corpus(300, seed=7)
        q = _rand((4, 64), 8)
        t0s = np.array([vf.min(), vf.min() + 500_000,
                        vf.max(), vf.min() - 10], np.int64)
        t1s = t0s + np.array([1, 300_000, 10**9, 5], np.int64)
        s_ref, i_ref = temporal_window_topk_ref(q, c, vf, vt, t0s, t1s, 6)
        s_k, i_k = temporal_window_topk(q, c, vf, vt, t0s, t1s, 6,
                                        bn=128, mode="interpret")
        np.testing.assert_allclose(np.asarray(s_k), s_ref,
                                   rtol=1e-5, atol=1e-5)
        # returned rows must overlap their OWN query's window
        s_k, i_k = np.asarray(s_k), np.asarray(i_k)
        for qi in range(4):
            fin = np.isfinite(s_k[qi])
            for j in np.asarray(i_k[qi][fin]):
                assert vf[j] < t1s[qi] and t0s[qi] < vt[j]

    def test_point_equals_window_of_one_microsecond(self):
        """temporal_topk(ts) must equal temporal_window_topk([ts, ts+1))
        exactly — the degenerate-window identity the engine relies on."""
        from repro.kernels.temporal_mask_score.ops import temporal_window_topk
        c, vf, vt, base = self._corpus(256, seed=9)
        q = _rand((3, 64), 10)
        ts = int(np.median(vf))
        b = np.full(3, ts, np.int64)
        for mode in ("ref", "interpret"):
            s_p, i_p = temporal_topk(q, c, vf, vt, ts, 5, mode=mode)
            s_w, i_w = temporal_window_topk(q, c, vf, vt, b, b + 1, 5,
                                            mode=mode)
            np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_w))
            np.testing.assert_array_equal(np.asarray(i_p), np.asarray(i_w))
