"""Background maintenance workers (DESIGN.md §13): bounded queues,
coalescing, retry/backoff, clean drain/stop — and a LiveVectorLake
serving correctly while seal/compaction/checkpointing run off-thread."""
import threading

import pytest

from repro.core.store import LiveVectorLake
from repro.serve.maintenance import MaintenanceWorker, StoreMaintenance
from repro.testing.faults import FAULTS

DIM = 64


@pytest.fixture(autouse=True)
def _clean_faults():
    FAULTS.reset()
    yield
    FAULTS.reset()


class TestMaintenanceWorker:
    def test_submit_runs_and_drain_blocks_until_done(self):
        w = MaintenanceWorker(name="t1")
        ran = []
        gate = threading.Event()
        w.submit("a", lambda: (gate.wait(1.0), ran.append("a")))
        w.submit("b", lambda: ran.append("b"))
        gate.set()
        assert w.drain(timeout=5.0)
        assert ran == ["a", "b"]
        w.stop()

    def test_same_key_coalesces_while_queued(self):
        w = MaintenanceWorker(name="t2")
        ran = []
        gate = threading.Event()
        # first job blocks the worker so subsequent submits stay queued
        w.submit("block", lambda: gate.wait(5.0))
        for _ in range(5):
            assert w.submit("x", lambda: ran.append("x"))
        gate.set()
        assert w.drain(timeout=5.0)
        assert ran == ["x"]                 # five wishes, one run
        w.stop()

    def test_full_queue_rejects_with_count_not_silence(self):
        w = MaintenanceWorker(name="t3", max_queue=2)
        gate = threading.Event()
        started = threading.Event()
        w.submit("block", lambda: (started.set(), gate.wait(5.0)))
        assert started.wait(5.0)            # blocker is OFF the queue
        assert w.submit("a", lambda: None)
        assert w.submit("b", lambda: None)
        assert not w.submit("c", lambda: None)   # past watermark
        from repro.obs import REGISTRY
        rej = REGISTRY.counter("maintenance_rejected", worker="t3")
        assert rej.value >= 1
        gate.set()
        w.stop()

    def test_transient_fault_retried_to_success(self):
        w = MaintenanceWorker(name="t4", max_retries=3, backoff_s=1e-4)
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")

        w.submit("j", flaky)
        assert w.drain(timeout=5.0)
        assert len(calls) == 3
        assert w.last_error is None
        w.stop()

    def test_retries_exhausted_counts_failure_loudly(self):
        w = MaintenanceWorker(name="t5", max_retries=1, backoff_s=1e-4)

        def doomed():
            raise RuntimeError("permanent")

        w.submit("j", doomed)
        assert w.drain(timeout=5.0)
        assert w.last_error is not None and w.last_error[0] == "j"
        from repro.obs import REGISTRY
        assert REGISTRY.counter("maintenance_failures",
                                worker="t5").value == 1
        w.stop()

    def test_stop_is_idempotent_and_drains(self):
        w = MaintenanceWorker(name="t6")
        ran = []
        w.submit("a", lambda: ran.append(1))
        assert w.stop(timeout=5.0)
        assert ran == [1]
        assert w.stop(timeout=1.0)          # second stop: no-op


class TestStoreMaintenance:
    def _fill(self, store, n=12, ts0=1_000_000):
        for i in range(n):
            store.ingest(f"doc{i}",
                         f"background maintenance sentence {i}.",
                         ts=ts0 + i * 1000)

    def test_deferred_mode_serves_identically(self, tmp_path):
        # oracle: inline maintenance (the default path)
        a = LiveVectorLake(str(tmp_path / "a"), dim=DIM, hot_capacity=8)
        self._fill(a)
        # deferred: same ingests with maintenance on a worker
        b = LiveVectorLake(str(tmp_path / "b"), dim=DIM, hot_capacity=8)
        maint = StoreMaintenance(b, backoff_s=1e-4).start()
        self._fill(b)
        assert maint.drain(timeout=10.0)
        maint.stop()
        for q in ("maintenance sentence 3.", "maintenance sentence 9."):
            ra = [(r.doc_id, r.position, round(r.score, 5))
                  for r in a.query(q, k=5)]
            rb = [(r.doc_id, r.position, round(r.score, 5))
                  for r in b.query(q, k=5)]
            assert ra == rb

    def test_worker_drives_checkpoints(self, tmp_path):
        s = LiveVectorLake(str(tmp_path / "c"), dim=DIM,
                           cold_checkpoint_interval=4)
        maint = StoreMaintenance(s, checkpoint_every=4,
                                 backoff_s=1e-4).start()
        assert s.cold.checkpoint_interval == 0   # inline cadence off
        self._fill(s, n=10)
        maint.drain(timeout=10.0)
        maint.stop()
        assert s.cold.checkpoint_interval == 4   # restored
        assert s.cold.stats()["checkpoints"] >= 1

    def test_concurrent_ingest_and_query_under_churn(self, tmp_path):
        s = LiveVectorLake(str(tmp_path / "d"), dim=DIM, hot_capacity=8)
        maint = StoreMaintenance(s, backoff_s=1e-4).start()
        errors = []

        def writer():
            try:
                self._fill(s, n=24)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        def reader():
            try:
                for _ in range(40):
                    s.query("maintenance sentence", k=3)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=writer),
              threading.Thread(target=reader),
              threading.Thread(target=reader)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30.0)
        assert maint.drain(timeout=10.0)
        maint.stop()
        assert errors == []
        assert len(s.hot) == 24
        r = s.query("maintenance sentence 17.", k=1)[0]
        assert r.doc_id == "doc17"

    def test_reopen_after_background_maintenance(self, tmp_path):
        root = str(tmp_path / "e")
        s = LiveVectorLake(root, dim=DIM, hot_capacity=8)
        maint = StoreMaintenance(s, backoff_s=1e-4).start()
        self._fill(s, n=16)
        maint.drain(timeout=10.0)
        maint.stop()
        s2 = LiveVectorLake(root, dim=DIM, hot_capacity=8)
        assert len(s2.hot) == 16
        r = s2.query("maintenance sentence 11.", k=1)[0]
        assert r.doc_id == "doc11"
