"""SchNet + recsys model smoke/correctness tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import schnet
from repro.models import recsys


def _random_graph(n=20, e=60, seed=0, d_feat=None):
    rng = np.random.default_rng(seed)
    edge_index = rng.integers(0, n, (2, e)).astype(np.int32)
    edge_dist = rng.random(e).astype(np.float32) * 8.0
    out = {"edge_index": jnp.asarray(edge_index),
           "edge_dist": jnp.asarray(edge_dist)}
    if d_feat:
        out["node_feat"] = jnp.asarray(
            rng.standard_normal((n, d_feat)).astype(np.float32))
    else:
        out["atom_z"] = jnp.asarray(rng.integers(1, 20, n).astype(np.int32))
    return out


class TestSchNet:
    def test_molecular_energy(self):
        cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20)
        params = schnet.init_params(jax.random.PRNGKey(0), cfg)
        g = _random_graph(n=20, e=60)
        h = schnet.forward(params, cfg, **g)
        assert h.shape == (20, 16)
        graph_ids = jnp.asarray(np.repeat([0, 1], 10).astype(np.int32))
        e = schnet.readout_energy(params, h, graph_ids, 2)
        assert e.shape == (2,) and np.all(np.isfinite(np.asarray(e)))

    def test_energy_grads(self):
        cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20)
        params = schnet.init_params(jax.random.PRNGKey(0), cfg)
        g = _random_graph(n=12, e=30)
        batch = dict(g, graph_ids=jnp.zeros(12, jnp.int32), n_graphs=1,
                     energy=jnp.asarray([1.0]))
        loss, grads = jax.value_and_grad(schnet.energy_loss)(
            params, cfg, batch)
        assert np.isfinite(float(loss))
        assert all(np.all(np.isfinite(np.asarray(v)))
                   for v in jax.tree_util.tree_leaves(grads))

    def test_node_classification(self):
        cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20,
                                  d_feat=32, n_classes=7)
        params = schnet.init_params(jax.random.PRNGKey(0), cfg)
        g = _random_graph(n=30, e=90, d_feat=32)
        batch = dict(g, labels=jnp.asarray(
            np.random.default_rng(0).integers(0, 7, 30).astype(np.int32)))
        loss = schnet.node_class_loss(params, cfg, batch)
        assert np.isfinite(float(loss))

    def test_isolated_nodes_ok(self):
        """segment_sum over an edge list must handle degree-0 nodes."""
        cfg = schnet.SchNetConfig(n_interactions=1, d_hidden=8, n_rbf=10)
        params = schnet.init_params(jax.random.PRNGKey(0), cfg)
        g = {"edge_index": jnp.asarray([[0], [1]], jnp.int32),
             "edge_dist": jnp.asarray([1.0]),
             "atom_z": jnp.asarray([1, 2, 3], jnp.int32)}
        h = schnet.forward(params, cfg, **g)
        assert np.all(np.isfinite(np.asarray(h)))


class TestRecsys:
    def test_fm_sum_square_trick(self):
        """FM via the O(nk) identity must equal the explicit O(n^2) sum."""
        cfg = recsys.FMConfig(n_sparse=5, embed_dim=4, vocab_per_field=50)
        params = recsys.fm_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        ids = jnp.asarray(
            (rng.integers(0, 50, (3, 5))
             + np.arange(5)[None, :] * 50).astype(np.int32))
        out = recsys.fm_forward(params, cfg, ids)
        # explicit pairwise
        v = np.asarray(params["v"])[np.asarray(ids)]     # (B, F, k)
        pair = np.zeros(3)
        for i in range(5):
            for j in range(i + 1, 5):
                pair += (v[:, i] * v[:, j]).sum(-1)
        expl = float(params["w0"]) + \
            np.asarray(params["w"])[np.asarray(ids)].sum(-1) + pair
        np.testing.assert_allclose(np.asarray(out), expl, rtol=1e-4,
                                   atol=1e-5)

    def test_fm_loss_grads(self):
        cfg = recsys.FMConfig(n_sparse=5, embed_dim=4, vocab_per_field=50)
        params = recsys.fm_init(jax.random.PRNGKey(0), cfg)
        ids = jnp.zeros((4, 5), jnp.int32)
        batch = {"ids": ids, "labels": jnp.asarray([0., 1., 1., 0.])}
        loss, g = jax.value_and_grad(recsys.fm_loss)(params, cfg, batch)
        assert np.isfinite(float(loss))

    def test_dlrm_forward_shapes(self):
        cfg = recsys.DLRMConfig(table_sizes=(100,) * 26)
        params = recsys.dlrm_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        dense = jnp.asarray(rng.random((4, 13)).astype(np.float32))
        sparse = jnp.asarray(rng.integers(0, 100, (4, 26, 1)).astype(np.int32))
        out = recsys.dlrm_forward(params, cfg, dense, sparse)
        assert out.shape == (4,) and np.all(np.isfinite(np.asarray(out)))

    def test_dlrm_multihot(self):
        cfg = recsys.DLRMConfig(table_sizes=(100,) * 26, multi_hot=4)
        params = recsys.dlrm_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        dense = jnp.asarray(rng.random((2, 13)).astype(np.float32))
        sparse = rng.integers(0, 100, (2, 26, 4)).astype(np.int32)
        sparse[:, :, 3] = -1                          # ragged bags via pad
        out = recsys.dlrm_forward(params, cfg, dense, jnp.asarray(sparse))
        assert np.all(np.isfinite(np.asarray(out)))

    def test_dlrm_loss_grads(self):
        cfg = recsys.DLRMConfig(table_sizes=(50,) * 26)
        params = recsys.dlrm_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        batch = {
            "dense": jnp.asarray(rng.random((4, 13)).astype(np.float32)),
            "sparse_ids": jnp.asarray(
                rng.integers(0, 50, (4, 26, 1)).astype(np.int32)),
            "labels": jnp.asarray([0., 1., 0., 1.]),
        }
        loss, g = jax.value_and_grad(recsys.dlrm_loss)(params, cfg, batch)
        assert np.isfinite(float(loss))
        assert all(np.all(np.isfinite(np.asarray(v)))
                   for v in jax.tree_util.tree_leaves(g))

    def test_widedeep(self):
        cfg = recsys.WideDeepConfig(n_sparse=6, embed_dim=8,
                                    vocab_per_field=40, mlp=(32, 16))
        params = recsys.widedeep_init(jax.random.PRNGKey(0), cfg)
        ids = jnp.asarray(np.random.default_rng(0).integers(
            0, 40, (4, 6)).astype(np.int32))
        batch = {"ids": ids, "labels": jnp.asarray([1., 0., 1., 0.])}
        loss, g = jax.value_and_grad(recsys.widedeep_loss)(
            params, cfg, batch)
        assert np.isfinite(float(loss))

    def test_bert4rec(self):
        cfg = recsys.bert4rec_config(n_items=200)
        from repro.models.transformer import init_params
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        tokens = rng.integers(4, 200, (2, 16)).astype(np.int32)
        labels = np.full((2, 16), -1, np.int32)
        tokens[:, 5] = 3                               # MASK
        labels[:, 5] = 42
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        loss = recsys.bert4rec_loss(params, cfg, batch)
        assert np.isfinite(float(loss))

    def test_retrieval_scoring(self):
        """1 query x N candidates via the fused top-k kernel."""
        rng = np.random.default_rng(0)
        cands = rng.standard_normal((1000, 16)).astype(np.float32)
        cands /= np.linalg.norm(cands, axis=1, keepdims=True)
        q = cands[42:43] + 0.01 * rng.standard_normal((1, 16)).astype(
            np.float32)
        scores, ids = recsys.score_candidates(jnp.asarray(q),
                                              jnp.asarray(cands), k=5)
        assert int(np.asarray(ids)[0, 0]) == 42
