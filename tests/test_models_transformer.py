"""Transformer model tests: forward shape/NaN, prefill==forward,
decode==teacher-forcing, MoE dispatch vs dense oracle, chunked attention
vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.moe import (MoEConfig, moe_block, moe_block_dense_ref,
                              moe_params)
from repro.models.transformer import (TransformerConfig, decode_step,
                                      forward, init_params, logits_fn,
                                      loss_fn, prefill)

TINY = TransformerConfig(
    name="tiny", vocab=128, d_model=32, n_layers=2, n_heads=4, n_kv=2,
    d_head=8, d_ff=64, act="swiglu", remat=False)

TINY_MOE = TransformerConfig(
    name="tiny-moe", vocab=128, d_model=32, n_layers=2, n_heads=4, n_kv=4,
    d_head=8, d_ff=64, act="swiglu", remat=False,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16, n_shared=1,
                  capacity_factor=8.0))   # drop-free for exact-match tests

TINY_BIAS = TransformerConfig(
    name="tiny-bias", vocab=128, d_model=32, n_layers=2, n_heads=4, n_kv=4,
    d_head=8, d_ff=64, act="sq_relu", qkv_bias=True, remat=False)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("cfg", [TINY, TINY_MOE, TINY_BIAS],
                         ids=lambda c: c.name)
class TestForward:
    def test_shapes_and_finite(self, cfg, rng):
        params = init_params(rng, cfg)
        tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
        hidden, aux = forward(params, tokens, cfg)
        assert hidden.shape == (2, 16, cfg.d_model)
        assert np.all(np.isfinite(np.asarray(hidden)))
        logits = logits_fn(params, hidden)
        assert logits.shape == (2, 16, cfg.vocab)

    def test_loss_and_grads_finite(self, cfg, rng):
        params = init_params(rng, cfg)
        tokens = jax.random.randint(rng, (2, 16), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        assert np.isfinite(float(loss))
        flat = jax.tree_util.tree_leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)

    def test_prefill_matches_forward(self, cfg, rng):
        params = init_params(rng, cfg)
        tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab)
        hidden, _ = forward(params, tokens, cfg)
        full_logits = logits_fn(params, hidden)
        pre_logits, cache, clen = prefill(params, tokens, cfg,
                                          cache_size=16)
        np.testing.assert_allclose(np.asarray(pre_logits),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-4, atol=2e-4)
        assert cache["k"].shape == (cfg.n_layers, 2, cfg.n_kv, 16,
                                    cfg.d_head)
        assert int(clen) == 12

    def test_decode_matches_teacher_forcing(self, cfg, rng):
        """Decoding token t with a cache must equal running the full
        sequence through forward (causal consistency)."""
        params = init_params(rng, cfg)
        b, s_p, n_dec = 2, 8, 3
        tokens = jax.random.randint(rng, (b, s_p + n_dec), 0, cfg.vocab)
        _, cache, clen = prefill(params, tokens[:, :s_p], cfg,
                                 cache_size=s_p + n_dec)
        for i in range(n_dec):
            step_logits, cache, clen = decode_step(
                params, tokens[:, s_p + i: s_p + i + 1], cache, clen, cfg)
            hidden, _ = forward(params, tokens[:, : s_p + i + 1], cfg)
            ref_logits = logits_fn(params, hidden)[:, -1]
            np.testing.assert_allclose(np.asarray(step_logits),
                                       np.asarray(ref_logits),
                                       rtol=5e-4, atol=5e-4)


class TestChunkedAttention:
    @pytest.mark.parametrize("sq,skv,chunk,causal", [
        (64, 64, 16, True), (32, 128, 32, True), (64, 64, 64, False),
        (16, 256, 128, True)])
    def test_matches_oracle(self, sq, skv, chunk, causal):
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (2, 4, sq, 16))
        k = jax.random.normal(jax.random.PRNGKey(2), (2, 2, skv, 16))
        v = jax.random.normal(jax.random.PRNGKey(3), (2, 2, skv, 16))
        out = chunked_attention(q, k, v, causal=causal, chunk=chunk)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_grad_flows(self):
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 32, 8))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 32, 8))
        v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 32, 8))
        g = jax.grad(lambda q: chunked_attention(q, k, v, chunk=8).sum())(q)
        assert np.all(np.isfinite(np.asarray(g)))


class TestMoE:
    def test_dispatch_matches_dense_oracle(self):
        """With generous capacity (no drops), sort-based dispatch must
        equal the O(E) dense reference."""
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, n_shared=1,
                        capacity_factor=8.0)
        params = moe_params(jax.random.PRNGKey(0), 24, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 24))
        out, aux = moe_block(params, x, cfg)
        ref = moe_block_dense_ref(params, x, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        assert np.isfinite(float(aux))

    def test_capacity_drops_bounded(self):
        """With capacity_factor ~1, some tokens drop but output stays
        finite and within norm bounds of the reference."""
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16,
                        capacity_factor=1.0)
        params = moe_params(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        out, _ = moe_block(params, x, cfg)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_grads_finite(self):
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=8, capacity_factor=2.0)
        params = moe_params(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

        def f(p):
            out, aux = moe_block(p, x, cfg)
            return jnp.sum(out ** 2) + aux

        g = jax.grad(f)(params)
        assert all(np.all(np.isfinite(np.asarray(v)))
                   for v in jax.tree_util.tree_leaves(g))


class TestEmbedder:
    def test_transformer_embedder(self):
        from repro.models.embedder import TransformerEmbedder, MINILM_CONFIG
        import dataclasses
        small = dataclasses.replace(MINILM_CONFIG, n_layers=2, vocab=512)
        emb = TransformerEmbedder(small, max_len=16)
        vecs = emb.embed(["hello world", "hello world", "other text"])
        assert vecs.shape == (3, 384)
        np.testing.assert_allclose(np.linalg.norm(vecs, axis=1), 1.0,
                                   rtol=1e-4)
        # determinism + identical text => identical embedding
        np.testing.assert_allclose(vecs[0], vecs[1])
