"""Observability layer tests (src/repro/obs — DESIGN.md §12):
hierarchical tracing (nesting, exception safety, the zero-allocation
no-op fast path), the metrics registry (labeled series, histogram
quantiles validated against numpy percentiles), the slow-query ring,
the batcher's registry-backed stats shim, the centralized
scan-accounting helper, and the fabric-wide e2e trace: one
``query_window_batch`` through a 4-shard ShardFabric produces one span
tree covering batcher -> planner -> every shard -> kernel dispatch
with per-shard rows_scanned summing to the planner total."""
import tempfile
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.obs import (Histogram, MetricsRegistry, SlowQueryLog,
                       geometric_bounds)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test sees a quiet slow-query log and enabled tracing (the
    registry is process-wide by design; tests use private registries
    or labeled series, so it is left alone)."""
    obs.set_enabled(True)
    obs.SLOW_QUERIES.reset()
    obs.SLOW_QUERIES.configure(budget_ms=100.0, capacity=32)
    yield
    obs.set_enabled(True)
    obs.SLOW_QUERIES.reset()
    obs.SLOW_QUERIES.configure(budget_ms=100.0, capacity=32)


class TestTrace:
    def test_span_nesting_builds_the_tree(self):
        with obs.trace("batch") as root:
            with obs.span("plan") as plan:
                for s in ("s00", "s01"):
                    with obs.span(f"shard:{s}") as sh:
                        sh.add("rows_scanned", 10)
                with obs.span("merge") as m:
                    m.add("candidates", 7)
            plan.add("queries", 2)
        assert root.name == "batch"
        assert [c.name for c in root.children] == ["plan"]
        assert [c.name for c in plan.children] == \
            ["shard:s00", "shard:s01", "merge"]
        assert root.total("rows_scanned") == 20
        assert plan.counters["queries"] == 2
        assert all(c.wall_ms >= 0 for c in plan.children)

    def test_add_lands_on_the_innermost_open_span(self):
        with obs.trace("t") as root:
            obs.add("x", 1)
            with obs.span("inner") as sp:
                obs.add("x", 5)
            obs.add("x", 2)
        assert root.counters["x"] == 3
        assert sp.counters["x"] == 5
        assert root.total("x") == 8

    def test_exception_marks_span_and_unwinds_stack(self):
        with pytest.raises(ValueError):
            with obs.trace("t") as root:
                with pytest.raises(KeyError):
                    with obs.span("a"):
                        raise KeyError("inner")
                # stack unwound: this span is a SIBLING of a, not a child
                with obs.span("b"):
                    pass
                raise ValueError("outer")
        assert [c.name for c in root.children] == ["a", "b"]
        assert root.children[0].status == "error:KeyError"
        assert root.children[1].status == "ok"
        assert root.status == "error:ValueError"
        assert obs.current_trace() is None      # contextvar reset

    def test_trace_feeds_slowlog_and_registry(self):
        obs.SLOW_QUERIES.configure(budget_ms=0.0)
        reg = obs.REGISTRY
        before = reg.histogram("trace_ms", trace="t_feed").count
        with obs.trace("t_feed"):
            pass
        assert reg.histogram("trace_ms", trace="t_feed").count \
            == before + 1
        assert obs.SLOW_QUERIES.observed == 1
        assert len(obs.SLOW_QUERIES.traces()) == 1

    def test_nested_trace_degrades_to_span(self):
        with obs.trace("outer") as root:
            with obs.trace("inner"):
                with obs.span("leaf"):
                    pass
        assert obs.SLOW_QUERIES.observed == 1   # ONE trace finished
        assert [c.name for c in root.children] == ["inner"]
        assert [c.name for c in root.children[0].children] == ["leaf"]

    def test_render_and_to_dict(self):
        obs.SLOW_QUERIES.configure(budget_ms=0.0)
        with obs.trace("t", intent="current") as root:
            with obs.span("scan") as sp:
                sp.add("rows_scanned", 42)
        tr = obs.SLOW_QUERIES.traces()[0]
        assert tr.intent == "current"
        text = tr.render()
        assert "scan" in text and "rows_scanned=42" in text
        d = tr.to_dict()
        assert d["spans"]["children"][0]["counters"]["rows_scanned"] == 42
        assert root.find("scan") == [sp]
        assert root.find_prefix("sc") == [sp]


class TestNoopFastPath:
    def test_span_without_trace_is_the_shared_singleton(self):
        assert obs.current_trace() is None
        assert obs.span("anything") is obs.NOOP_SPAN
        assert obs.span("other") is obs.NOOP_SPAN

    def test_disabled_tracing_is_noop_even_for_trace(self):
        obs.set_enabled(False)
        assert obs.trace("t") is obs.NOOP_SPAN
        with obs.trace("t") as sp:
            sp.add("x", 1)
            assert obs.span("y") is obs.NOOP_SPAN
        assert obs.SLOW_QUERIES.observed == 0

    def test_noop_path_allocates_nothing(self):
        def probe(n):
            for _ in range(n):
                with obs.span("fused_scan") as sp:
                    sp.add("rows_scanned", 128)
                obs.add("bytes_streamed", 4096)

        probe(100)                               # warm caches
        tracemalloc.start()
        base = tracemalloc.get_traced_memory()[0]
        probe(10_000)
        grown = tracemalloc.get_traced_memory()[0] - base
        tracemalloc.stop()
        # zero per-iteration allocation; allow a tiny constant slack
        assert grown < 512, f"no-op path allocated {grown} bytes"

    def test_scan_row_reads_counts_without_a_trace(self):
        reg = obs.REGISTRY
        c = reg.counter("scan_row_reads", source="test_noop")
        v0 = c.value
        assert obs.scan_row_reads(100, 4, per_query=False,
                                  source="test_noop") == 100
        assert obs.scan_row_reads(100, 4, per_query=True,
                                  source="test_noop") == 400
        assert c.value == v0 + 500


class TestMetrics:
    def test_counter_gauge_series_by_label(self):
        reg = MetricsRegistry()
        reg.counter("reads", tier="hot").inc()
        reg.counter("reads", tier="hot").inc(4)
        reg.counter("reads", tier="cold").inc()
        reg.gauge("depth", shard="s00").set(7)
        snap = reg.snapshot()
        assert snap["counters"]["reads{tier=hot}"] == 5
        assert snap["counters"]["reads{tier=cold}"] == 1
        assert snap["gauges"]["depth{shard=s00}"] == 7
        assert "reads{tier=hot}" in reg.to_json()
        reg.reset()
        assert reg.snapshot() == {"counters": {}, "gauges": {},
                                  "histograms": {}}

    def test_label_key_is_order_independent(self):
        reg = MetricsRegistry()
        a = reg.counter("m", tier="hot", shard="s01")
        b = reg.counter("m", shard="s01", tier="hot")
        assert a is b

    def test_histogram_quantiles_vs_numpy(self):
        rng = np.random.default_rng(7)
        # latency-shaped data spanning several bucket decades
        samples = np.exp(rng.normal(1.5, 1.0, 20_000))
        h = Histogram()
        for v in samples:
            h.observe(float(v))
        for q in (0.5, 0.9, 0.99, 0.999):
            got = h.quantile(q)
            want = float(np.percentile(samples, q * 100))
            # bucket factor 1.15 bounds the relative error at ~7.5%
            assert abs(got - want) / want < 0.08, (q, got, want)
        s = h.summary()
        assert s["count"] == len(samples)
        assert h.min == pytest.approx(samples.min())
        assert h.max == pytest.approx(samples.max())
        assert h.mean == pytest.approx(samples.mean(), rel=1e-6)
        assert set(s) == {"count", "sum", "mean", "min", "max",
                          "p50", "p99", "p999"}

    def test_histogram_without_storing_samples(self):
        h = Histogram()
        for v in range(100_000):
            h.observe(v * 0.01)
        # fixed memory: bucket counts only, no sample list anywhere
        assert not hasattr(h, "samples")
        assert len(h.counts) == len(h.bounds) + 1
        assert h.count == 100_000

    def test_histogram_edge_cases(self):
        h = Histogram()
        assert h.quantile(0.5) is None
        assert h.summary() == {"count": 0}
        h.observe(5.0)
        assert h.quantile(0.0) == pytest.approx(5.0)
        assert h.quantile(1.0) == pytest.approx(5.0)
        h2 = Histogram()
        h2.observe(10.0 ** 9)                    # beyond the last bound
        assert h2.quantile(0.5) == pytest.approx(10.0 ** 9)

    def test_geometric_bounds_cover_the_latency_range(self):
        b = geometric_bounds()
        assert b[0] <= 1e-3 and b[-1] >= 1e5
        ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
        assert all(abs(r - 1.15) < 1e-9 for r in ratios)


class TestSlowQueryLog:
    def _mk_trace(self, name, wall_ms):
        from repro.obs.trace import Trace
        tr = Trace(name)
        tr.wall_ms = tr.root.wall_ms = wall_ms
        return tr

    def test_ring_retains_only_over_budget_and_evicts(self):
        log = SlowQueryLog(budget_ms=10.0, capacity=4)
        for i in range(10):
            log.observe(self._mk_trace(f"t{i}", 5.0 if i % 2 else 20.0))
        assert log.observed == 10
        kept = log.traces()
        assert len(kept) == 4                    # ring evicted the rest
        assert [t.name for t in kept] == ["t2", "t4", "t6", "t8"]
        assert log.slowest.wall_ms == 20.0
        s = log.summary()
        assert s["over_budget_retained"] == 4
        assert s["observed"] == 10

    def test_slowest_is_tracked_even_under_budget(self):
        log = SlowQueryLog(budget_ms=100.0, capacity=4)
        log.observe(self._mk_trace("fast", 1.0))
        log.observe(self._mk_trace("faster", 0.5))
        assert log.traces() == []
        assert log.slowest.name == "fast"

    def test_configure_shrink_keeps_newest(self):
        log = SlowQueryLog(budget_ms=0.0, capacity=8)
        for i in range(6):
            log.observe(self._mk_trace(f"t{i}", 1.0))
        log.configure(capacity=2)
        assert [t.name for t in log.traces()] == ["t4", "t5"]
        log.configure(budget_ms=50.0)
        assert log.budget_ms == 50.0


class TestBatcherMetrics:
    def test_stats_shim_matches_registry_series(self):
        from repro.serve.batcher import Batcher
        b = Batcher(lambda ps: [p * 2 for p in ps], max_batch=4)
        for i in range(6):
            b.submit(i)
        b.drain()
        assert b.stats == {"batches": 2, "requests": 6, "hedges": 0,
                           "failed_batches": 0, "rejected": 0,
                           "deadline_expired": 0, "mean_batch_size": 3.0}
        snap = obs.REGISTRY.snapshot()
        key = f"batcher_requests{{batcher={b.label}}}"
        assert snap["counters"][key] == 6

    def test_queue_depth_and_time_in_queue_histograms(self):
        from repro.serve.batcher import Batcher
        b = Batcher(lambda ps: list(ps), max_batch=8)
        for i in range(5):
            b.submit(i)
        b.drain()
        depth = obs.REGISTRY.histogram("batcher_queue_depth",
                                       batcher=b.label)
        wait = obs.REGISTRY.histogram("batcher_time_in_queue_ms",
                                      batcher=b.label)
        assert depth.count == 1 and depth.max == 5.0
        assert wait.count == 5 and wait.min >= 0.0

    def test_batch_opens_one_trace(self):
        from repro.serve.batcher import Batcher
        obs.SLOW_QUERIES.configure(budget_ms=0.0)
        b = Batcher(lambda ps: list(ps), max_batch=8,
                    bucket_fn=lambda p: p % 2)
        for i in range(4):
            b.submit(i)
        b.drain()
        traces = obs.SLOW_QUERIES.traces()
        assert len(traces) == 2                  # one per bucket batch
        assert {t.intent for t in traces} == {"0", "1"}
        assert all(t.root.counters["batch_size"] == 2 for t in traces)


class TestScanAccountingConvention:
    def test_helper_is_the_single_convention_point(self):
        # fused/solo: once per batch, independent of nq
        assert obs.scan_row_reads(1000, 8, per_query=False,
                                  source="t1") == 1000
        # per-query sources: avg per query x nq
        assert obs.scan_row_reads(250, 8, per_query=True,
                                  source="t1") == 2000

    def test_index_paths_report_through_the_helper(self):
        from repro.core.types import ChunkRecord
        from repro.index.lsm import SegmentedIndex
        rng = np.random.default_rng(3)
        reg = obs.REGISTRY
        with tempfile.TemporaryDirectory() as root:
            idx = SegmentedIndex(8, mem_capacity=64, root=root,
                                 ivf_min_rows=128)
            idx.insert([ChunkRecord(
                chunk_id=f"c{i}", doc_id=f"d{i}", position=0,
                valid_from=1 + i, text=f"row {i}",
                embedding=rng.normal(size=8).astype(np.float32))
                for i in range(300)])
            fused0 = reg.counter("scan_row_reads", source="fused").value
            ivf0 = reg.counter("scan_row_reads", source="ivf").value
            solo0 = reg.counter("scan_row_reads", source="solo").value
            s0 = idx._scan_scanned
            idx.search(rng.normal(size=(2, 8)).astype(np.float32), k=5)
            moved = (
                (reg.counter("scan_row_reads", source="fused").value
                 - fused0)
                + (reg.counter("scan_row_reads", source="ivf").value
                   - ivf0)
                + (reg.counter("scan_row_reads", source="solo").value
                   - solo0))
            # the index's own accounting is EXACTLY the helper's sum
            assert moved == idx._scan_scanned - s0 > 0


class TestFabricEndToEnd:
    def test_window_batch_trace_covers_every_layer(self):
        obs.SLOW_QUERIES.configure(budget_ms=0.0)
        with tempfile.TemporaryDirectory() as root:
            from repro.shard.shard import ShardFabric
            fab = ShardFabric(root, n_shards=4, dim=32, replicas=2)
            for i in range(8):
                fab.ingest(f"doc{i}", f"alpha topic{i} first text. " * 3,
                           ts=1000 + i)
            for i in range(8):
                fab.ingest(f"doc{i}", f"alpha topic{i} revised text. " * 3,
                           ts=2000 + i)
            obs.SLOW_QUERIES.reset()
            b = fab.query_batcher(k=3)
            b.submit(("alpha topic1", None, (1500, 2500)))
            b.submit(("alpha topic2", None, (1500, 2500)))
            b.drain()
            traces = obs.SLOW_QUERIES.traces()
            assert len(traces) == 1              # one batch, one trace
            tr = traces[0]
            assert tr.root.name == "batch"
            assert "comparative" in tr.intent
            plan = tr.root.find("plan")
            assert len(plan) == 1
            shard_spans = plan[0].find_prefix("shard:")
            assert {s.name for s in shard_spans} == \
                {"shard:s00", "shard:s01", "shard:s02", "shard:s03"}
            per_shard = [s.total("rows_scanned") for s in shard_spans]
            assert all(r > 0 for r in per_shard)
            # per-shard subtree totals sum to the planner/root total
            assert sum(per_shard) == plan[0].total("rows_scanned") \
                == tr.root.total("rows_scanned")
            # kernel dispatches appear with rows + bytes
            kernels = tr.root.find_prefix("kernel:")
            assert kernels
            assert all(sp.counters.get("rows", 0) > 0 for sp in kernels)
            assert all(sp.counters.get("bytes_streamed", 0) > 0
                       for sp in kernels)
            assert tr.root.find("merge")
            # health(): one call returns topology + metrics + slowlog
            h = fab.health()
            assert h["planner"]["gathers"] == 1
            assert any(k.startswith("query_latency_ms")
                       for k in h["metrics"]["histograms"])
            assert h["slow_queries"]["observed"] == 1

    def test_trace_overhead_smoke(self):
        """The no-op fast path must not measurably slow an uninstru-
        mented caller (full gate lives in benchmarks/obs_overhead)."""
        with tempfile.TemporaryDirectory() as root:
            from repro.core.types import ChunkRecord
            from repro.index.lsm import SegmentedIndex
            rng = np.random.default_rng(0)
            idx = SegmentedIndex(16, mem_capacity=2048, root=root)
            idx.insert([ChunkRecord(
                chunk_id=f"c{i}", doc_id=f"d{i}", position=0,
                valid_from=1 + i, text="t",
                embedding=rng.normal(size=16).astype(np.float32))
                for i in range(512)])
            q = rng.normal(size=(4, 16)).astype(np.float32)
            r_noop = idx.search(q, k=5)
            with obs.trace("t"):
                r_traced = idx.search(q, k=5)
            # tracing never changes results
            assert [[x.chunk_id for x in row] for row in r_noop] == \
                [[x.chunk_id for x in row] for row in r_traced]


class TestThreadSafety:
    """Serving threads + maintenance workers hammer the same series
    concurrently; totals must be exact (DESIGN.md §13)."""

    def test_counter_hammer_exact_total(self):
        import threading
        reg = MetricsRegistry()
        c = reg.counter("hits")
        N, M = 8, 2000

        def inc():
            for _ in range(M):
                c.inc()

        ts = [threading.Thread(target=inc) for _ in range(N)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value == N * M

    def test_histogram_hammer_exact_count_and_sum(self):
        import threading
        h = Histogram()
        N, M = 8, 1000

        def observe():
            for i in range(M):
                h.observe(1.0)

        ts = [threading.Thread(target=observe) for _ in range(N)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert h.count == N * M
        assert abs(h.sum - N * M) < 1e-6
        assert h.summary()["p50"] is not None

    def test_registry_get_or_create_single_instance_under_race(self):
        import threading
        reg = MetricsRegistry()
        got = []
        barrier = threading.Barrier(8)

        def get():
            barrier.wait()
            got.append(reg.counter("one", tier="hot"))

        ts = [threading.Thread(target=get) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert all(g is got[0] for g in got)

    def test_slowlog_hammer_observed_exact(self):
        import threading
        log = SlowQueryLog(budget_ms=0.0, capacity=16)

        class T:
            name = "t"
            intent = None
            wall_ms = 1.0

        def observe():
            for _ in range(500):
                log.observe(T())

        ts = [threading.Thread(target=observe) for _ in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert log.observed == 3000
        assert len(log.traces()) == 16


class TestSubtrace:
    def test_worker_thread_spans_graft_into_parent(self):
        import threading
        roots = {}

        def worker(name):
            with obs.subtrace(name) as sroot:
                with obs.span("inner"):
                    obs.add("rows", 7)
            roots[name] = sroot

        with obs.trace("parent") as proot:
            with obs.span("plan") as plan_sp:
                ts = [threading.Thread(target=worker, args=(f"shard:s{i}",))
                      for i in range(3)]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
                for name in sorted(roots):
                    plan_sp.children.append(roots[name])
        plan = proot.find("plan")[0]
        assert len(plan.children) == 3
        for child in plan.children:
            assert child.name.startswith("shard:")
            assert child.wall_ms >= 0.0
            assert child.total("rows") == 7

    def test_subtrace_does_not_feed_registry_or_slowlog(self):
        obs.REGISTRY.reset()
        obs.SLOW_QUERIES.reset()
        with obs.subtrace("detached"):
            with obs.span("x"):
                pass
        assert obs.SLOW_QUERIES.observed == 0
        snap = obs.REGISTRY.snapshot()
        assert not any(k.startswith("trace_ms") for k in snap["counters"])
        assert not any(k.startswith("trace_ms")
                       for k in snap["histograms"])

    def test_subtrace_noop_when_disabled(self):
        obs.set_enabled(False)
        try:
            assert obs.subtrace("x") is obs.NOOP_SPAN
        finally:
            obs.set_enabled(True)


class TestGaugeAndSnapshots:
    """PR 9 satellites: the locked Gauge (inc is read-modify-write) and
    the snapshot/delta primitive the SLO engine's windows ride on."""

    def test_gauge_set_and_inc(self):
        reg = MetricsRegistry()
        g = reg.gauge("queue_depth", shard="s00")
        g.set(3.0)
        g.inc(2.0)
        g.inc(-1.0)
        assert g.value == 4.0
        assert reg.gauge("queue_depth", shard="s00") is g

    def test_gauge_inc_hammer_exact_total(self):
        import threading
        reg = MetricsRegistry()
        g = reg.gauge("hammer")
        n_threads, per = 8, 5_000

        def worker():
            for _ in range(per):
                g.inc(1.0)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        # a lock-free read-modify-write would drop updates here
        assert g.value == n_threads * per

    def test_snapshot_delta_isolates_new_traffic(self):
        h = Histogram(bounds=[1.0, 10.0, 100.0])
        for v in (0.5, 5.0):
            h.observe(v)
        base = h.snapshot_at()
        for v in (5.0, 50.0, 500.0):
            h.observe(v)
        d = h.delta(base)
        assert d.count == 3
        assert d.sum == pytest.approx(555.0)
        assert d.counts == (0, 1, 1, 1)
        # the live histogram is untouched
        assert h.count == 5

    def test_delta_degrades_when_prev_is_ahead(self):
        # registry reset underneath: prev has MORE than current
        h = Histogram(bounds=[1.0, 10.0])
        h.observe(5.0)
        h.observe(5.0)
        stale = h.snapshot_at()
        h2 = Histogram(bounds=[1.0, 10.0])
        h2.observe(5.0)
        d = h2.delta(stale)
        assert d.count == 1       # current state, not negative counts

    def test_count_le_interpolates_crossing_bucket(self):
        h = Histogram(bounds=[0.0, 10.0, 20.0])
        for _ in range(10):
            h.observe(5.0)        # all land in (0, 10]
        s = h.snapshot_at()
        assert s.count_le(10.0) == pytest.approx(10.0)
        assert s.count_le(5.0) == pytest.approx(5.0)   # half the bucket
        assert s.count_le(0.0) == pytest.approx(0.0)
        assert s.fraction_over(5.0) == pytest.approx(0.5)
        assert s.fraction_over(1e9) == 0.0

    def test_count_le_never_interpolates_overflow(self):
        h = Histogram(bounds=[1.0, 10.0])
        h.observe(500.0)          # overflow bucket
        s = h.snapshot_at()
        assert s.count_le(10.0) == 0.0
        assert s.fraction_over(10.0) == 1.0

    def test_parse_series_key_round_trip(self):
        from repro.obs import parse_series_key
        assert parse_series_key("plain") == ("plain", {})
        assert parse_series_key("m{a=1,b=x}") == ("m", {"a": "1",
                                                        "b": "x"})
        reg = MetricsRegistry()
        reg.counter("m", b="x", a="1").inc(1)
        ((key, _),), _, _ = reg.export_state()
        assert parse_series_key(key) == ("m", {"a": "1", "b": "x"})


class TestIntentBudgets:
    """Slow-query budgets are per-intent (DESIGN.md §15): maintenance
    jobs get a deliberately high default so compactions don't evict
    real serving outliers."""

    def _tr(self, intent, wall_ms, name="request"):
        from repro.obs.trace import Trace
        tr = Trace(name, intent)
        tr.wall_ms = tr.root.wall_ms = wall_ms
        return tr

    def test_maintenance_default_budget(self):
        assert obs.SLOW_QUERIES.budget_for("maintenance") == 10_000.0
        assert obs.SLOW_QUERIES.budget_for("current") == 100.0
        assert obs.SLOW_QUERIES.budget_for(None) == 100.0

    def test_token_matching_against_rendered_intents(self):
        obs.SLOW_QUERIES.configure(intent_budgets={"at": 2000.0})
        bucket = "(TemporalIntent(mode='at', at=5000), None)"
        assert obs.SLOW_QUERIES.budget_for(bucket) == 2000.0
        assert obs.SLOW_QUERIES.budget_for("comparative") == 100.0

    def test_per_intent_retention(self):
        # 500ms maintenance: under ITS budget; 500ms serving: over
        obs.SLOW_QUERIES.observe(self._tr("maintenance", 500.0,
                                          name="maint:compact"))
        obs.SLOW_QUERIES.observe(self._tr("current", 500.0))
        retained = obs.SLOW_QUERIES.traces()
        assert [t.intent for t in retained] == ["current"]
        # the slowest tracker still sees everything
        assert obs.SLOW_QUERIES.observed == 2

    def test_configure_merges_and_none_removes(self):
        obs.SLOW_QUERIES.configure(intent_budgets={"at": 2000.0})
        obs.SLOW_QUERIES.configure(intent_budgets={"window": 1500.0})
        got = obs.SLOW_QUERIES.summary()["intent_budgets"]
        assert got == {"maintenance": 10_000.0, "at": 2000.0,
                       "window": 1500.0}
        obs.SLOW_QUERIES.configure(intent_budgets={"maintenance": None})
        assert obs.SLOW_QUERIES.budget_for("maintenance") == 100.0

    def test_maintenance_jobs_run_traced(self):
        from repro.serve.maintenance import MaintenanceWorker
        worker = MaintenanceWorker().start()
        try:
            assert worker.submit("compact", lambda: None)
            assert worker.drain(timeout=5.0)
        finally:
            worker.stop()
        tr = obs.SLOW_QUERIES.slowest
        assert tr is not None
        assert tr.name == "maint:compact"
        assert tr.intent == "maintenance"


class TestTenantMetering:
    """Per-tenant scan metering (DESIGN.md §15): when the active trace
    carries a tenant attribute, scan_row_reads bills reads (and with
    row_bytes, bytes) to tenant-labeled series."""

    def test_helper_bills_reads_and_bytes_to_tenant(self):
        obs.REGISTRY.reset()
        with obs.trace("request", tenant="acme"):
            obs.scan_row_reads(1024, nq=4, per_query=False,
                               source="fused", row_bytes=384)
            obs.scan_row_reads(100, nq=4, per_query=True,
                               source="ivf", row_bytes=1536)
        c = obs.REGISTRY.snapshot()["counters"]
        assert c["scan_row_reads{tenant=acme}"] == 1024 + 400
        assert c["scan_bytes_streamed{tenant=acme}"] == \
            1024 * 384 + 400 * 1536
        # the per-source convention series are untouched by tenancy
        assert c["scan_row_reads{source=fused}"] == 1024
        assert c["scan_row_reads{source=ivf}"] == 400

    def test_no_tenant_attr_means_no_tenant_series(self):
        obs.REGISTRY.reset()
        with obs.trace("request"):
            obs.scan_row_reads(64, nq=1, per_query=False,
                               source="fused", row_bytes=4)
        c = obs.REGISTRY.snapshot()["counters"]
        assert not any("tenant=" in k for k in c)
        assert c["scan_row_reads{source=fused}"] == 64

    def test_index_scan_bills_bytes_end_to_end(self):
        from repro.core.types import ChunkRecord
        from repro.index.lsm import SegmentedIndex
        obs.REGISTRY.reset()
        rng = np.random.default_rng(0)
        dim = 16
        with tempfile.TemporaryDirectory() as root:
            idx = SegmentedIndex(dim, mem_capacity=64, root=root)
            idx.insert([ChunkRecord(chunk_id=f"c{i}", doc_id=f"d{i}",
                                    position=0, valid_from=1 + i,
                                    text="r",
                                    embedding=rng.normal(size=dim))
                        for i in range(32)])
            with obs.trace("request", tenant="acme"):
                idx.search(rng.normal(size=(2, dim)), k=4)
        c = obs.REGISTRY.snapshot()["counters"]
        reads = c["scan_row_reads{tenant=acme}"]
        assert reads > 0
        # row_bytes plumbed from the index: dim bytes (int8) or dim*4
        assert c["scan_bytes_streamed{tenant=acme}"] in \
            (reads * dim, reads * dim * 4)


class TestRooflineConstant:
    def test_cost_peak_mirrors_benchmarks_roofline(self):
        # src must not import from benchmarks/, so obs/cost.py
        # duplicates the constant — this is the drift guard
        from benchmarks.roofline import HBM_BW
        assert obs.PEAK_HBM_GBS * 1e9 == HBM_BW
