"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an OPTIONAL dev dependency (requirements-dev.txt):
this module skips cleanly when it is absent so ``pytest -x`` never dies
at collection on a minimal environment.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cdc import detect_changes, positional_diff
from repro.core.chunking import chunk_document, split_blocks
from repro.core.hashing import chunk_hash, normalize
from repro.kernels.common import le_i64, lt_i64, split_i64

# text strategy: paragraphs of printable words
_word = st.text(alphabet=st.characters(min_codepoint=33, max_codepoint=126),
                min_size=1, max_size=8)
_para = st.lists(_word, min_size=1, max_size=12).map(" ".join)
_doc = st.lists(_para, min_size=0, max_size=10).map("\n\n".join)


class TestHashingProperties:
    @given(_para)
    @settings(max_examples=200, deadline=None)
    def test_normalize_idempotent(self, text):
        assert normalize(normalize(text)) == normalize(text)

    @given(_para)
    @settings(max_examples=200, deadline=None)
    def test_hash_whitespace_case_invariant(self, text):
        assert chunk_hash(text) == chunk_hash("  " + text.upper() + " \t")

    @given(_para, _para)
    @settings(max_examples=200, deadline=None)
    def test_distinct_normalized_content_distinct_hash(self, a, b):
        if normalize(a) != normalize(b):
            assert chunk_hash(a) != chunk_hash(b)


class TestChunkingProperties:
    @given(_doc)
    @settings(max_examples=100, deadline=None)
    def test_positions_sequential(self, doc):
        chunks = chunk_document(doc)
        assert [c.position for c in chunks] == list(range(len(chunks)))

    @given(_doc)
    @settings(max_examples=100, deadline=None)
    def test_chunking_deterministic(self, doc):
        a = [c.chunk_id for c in chunk_document(doc)]
        b = [c.chunk_id for c in chunk_document(doc)]
        assert a == b

    @given(_doc)
    @settings(max_examples=100, deadline=None)
    def test_blocks_nonempty(self, doc):
        for blk in split_blocks(doc):
            assert blk.strip()


class TestCDCProperties:
    @given(_doc)
    @settings(max_examples=100, deadline=None)
    def test_self_diff_is_empty(self, doc):
        chunks = chunk_document(doc)
        cs = detect_changes(chunks, [c.chunk_id for c in chunks])
        assert not cs.new and not cs.modified and not cs.deleted
        assert not cs.moved
        assert len(cs.unchanged) == len(chunks)

    @given(_doc, _doc)
    @settings(max_examples=100, deadline=None)
    def test_class_partition(self, old_doc, new_doc):
        """Every new-version chunk lands in exactly one class."""
        old = [c.chunk_id for c in chunk_document(old_doc)]
        new_chunks = chunk_document(new_doc)
        cs = detect_changes(new_chunks, old)
        n = (len(cs.new) + len(cs.modified) + len(cs.unchanged)
             + len(cs.moved))
        assert n == len(new_chunks)

    @given(_doc, _doc)
    @settings(max_examples=100, deadline=None)
    def test_positional_diff_conserves_slots(self, old_doc, new_doc):
        old = [c.chunk_id for c in chunk_document(old_doc)]
        new_chunks = chunk_document(new_doc)
        close, append = positional_diff(new_chunks, old)
        n_old, n_new = len(old), len(new_chunks)
        # final live record count must equal the new version's chunk count
        assert n_old - len(close) + len(append) == n_new
        assert all(p < n_old for p in close)
        assert all(p < n_new for p in append)

    @given(_doc, _doc)
    @settings(max_examples=60, deadline=None)
    def test_embedding_work_bounded(self, old_doc, new_doc):
        """to_embed never exceeds the new version's chunk count, and is
        zero when content is a permutation (move-only update)."""
        old = [c.chunk_id for c in chunk_document(old_doc)]
        new_chunks = chunk_document(new_doc)
        cs = detect_changes(new_chunks, old)
        assert len(cs.to_embed) <= len(new_chunks)


class TestTimestampSplitProperties:
    @given(st.lists(st.integers(min_value=0, max_value=2**62),
                    min_size=1, max_size=50),
           st.integers(min_value=0, max_value=2**62))
    @settings(max_examples=200, deadline=None)
    def test_split_i64_comparisons_exact(self, xs, ts):
        """Lexicographic (hi, lo) compare == int64 compare, always."""
        import jax.numpy as jnp
        xs_np = np.array(xs, np.int64)
        x_hi, x_lo = split_i64(xs_np)
        t_hi, t_lo = split_i64(np.array([ts], np.int64))
        le = np.asarray(le_i64(jnp.asarray(x_hi),
                               jnp.asarray(x_lo.view(np.int32)).astype(jnp.uint32),
                               jnp.asarray(t_hi)[0],
                               jnp.asarray(t_lo.view(np.int32)).astype(jnp.uint32)[0]))
        np.testing.assert_array_equal(le, xs_np <= ts)
        lt = np.asarray(lt_i64(jnp.asarray(x_hi),
                               jnp.asarray(x_lo.view(np.int32)).astype(jnp.uint32),
                               jnp.asarray(t_hi)[0],
                               jnp.asarray(t_lo.view(np.int32)).astype(jnp.uint32)[0]))
        np.testing.assert_array_equal(lt, xs_np < ts)


class TestValiditySemantics:
    @given(st.lists(st.tuples(st.integers(0, 100), st.integers(0, 100)),
                    min_size=1, max_size=20),
           st.integers(0, 100))
    @settings(max_examples=100, deadline=None)
    def test_half_open_interval_filter(self, pairs, ts):
        """snapshot validity semantics: valid iff vf <= ts < vt."""
        vf = np.array([min(a, b) for a, b in pairs], np.int64)
        vt = np.array([max(a, b) + 1 for a, b in pairs], np.int64)
        valid = (vf <= ts) & (ts < vt)
        # boundary: at ts == vf valid; at ts == vt invalid
        for i in range(len(pairs)):
            if ts == vf[i]:
                assert valid[i]
            if ts == vt[i]:
                assert not valid[i]


class TestVectorizedMergeProperties:
    """The batched engine's array-native top-k merge (DESIGN.md §8) must
    agree exactly with the old per-candidate tuple-sort merge, including
    on exact score ties, -inf sentinels, and non-authoritative rows."""

    @staticmethod
    def _merge_ref(scores, gids, authority, k):
        """Old merge: stable sort by -score (Python ``sorted`` keeps
        candidate order on ties), skip dead/non-authoritative, take k."""
        out = []
        for qi in range(scores.shape[0]):
            picked = []
            for s, g in sorted(((float(scores[qi, j]), int(gids[qi, j]))
                                for j in range(scores.shape[1])),
                               key=lambda t: -t[0]):
                if len(picked) == k:
                    break
                if g < 0 or not np.isfinite(s) or not authority[g]:
                    continue
                picked.append((np.float32(s), g))
            out.append(picked)
        return out

    @given(st.data())
    @settings(max_examples=150, deadline=None)
    def test_merge_matches_tuple_sort(self, data):
        from repro.index.lsm import merge_topk_candidates
        nq = data.draw(st.integers(1, 5))
        w = data.draw(st.integers(1, 32))
        n_rows = data.draw(st.integers(1, 48))
        k = data.draw(st.integers(1, 10))
        # quantized scores: exact ties are the interesting regime
        scores = np.array(data.draw(st.lists(
            st.lists(st.sampled_from([-1.5, -1.0, 0.0, 0.5, 1.0,
                                      float("-inf")]),
                     min_size=w, max_size=w),
            min_size=nq, max_size=nq)), np.float32)
        gids = np.array(data.draw(st.lists(
            st.lists(st.integers(-1, n_rows - 1), min_size=w, max_size=w),
            min_size=nq, max_size=nq)), np.int64)
        authority = np.array(data.draw(st.lists(st.booleans(),
                                                min_size=n_rows,
                                                max_size=n_rows)), bool)
        top_s, top_g = merge_topk_candidates(scores, gids, authority, k)
        assert top_s.shape == (nq, k) and top_g.shape == (nq, k)
        ref = self._merge_ref(scores, gids, authority, k)
        for qi in range(nq):
            got = [(top_s[qi, j], int(top_g[qi, j]))
                   for j in range(k) if top_g[qi, j] >= 0]
            assert got == ref[qi]
            # padding after the last winner is all (-inf, -1)
            tail = top_g[qi, len(got):]
            assert (tail == -1).all()
