"""ISSUE 5: the quantized scan fabric — round-trip determinism, recall
gates vs the fp32 oracle for fused/IVF/temporal paths, scan-accounting
consistency, and the fp32 winners-row rescore machinery."""
import os
import tempfile

import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.core.types import ChunkRecord
from repro.data.corpus import generate_corpus
from repro.index.lsm import SegmentedIndex
from repro.index.quant import (AppendOnlyF32File, F32Rows, data_scale,
                               dequantize, fixed_scale, quantize_int8,
                               quantize_rows, rescore_topk)
from repro.index.segment import Segment


def _unit(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _records(n, d=64, seed=0, docs=97):
    emb = _unit((n, d), seed)
    return [ChunkRecord(chunk_id=f"c{seed}-{i}", doc_id=f"d{i % docs}",
                        position=i // docs, valid_from=1000 + i,
                        text=f"text {i}", embedding=emb[i])
            for i in range(n)]


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------
class TestQuantPrimitives:
    def test_quantize_deterministic(self):
        emb = _unit((500, 96), 1)
        q1, s1 = quantize_int8(emb)
        q2, s2 = quantize_int8(emb.copy())
        np.testing.assert_array_equal(q1, q2)
        np.testing.assert_array_equal(s1, s2)

    def test_round_trip_error_bounded(self):
        emb = _unit((200, 128), 2)
        for scale in (data_scale(emb), fixed_scale(128)):
            deq = dequantize(quantize_rows(emb, scale), scale)
            # symmetric rounding: error <= scale/2 per component
            assert np.all(np.abs(deq - emb) <= scale[None, :] / 2 + 1e-7)

    def test_fixed_scale_covers_normalized_rows(self):
        emb = _unit((100, 64), 3)
        q8 = quantize_rows(emb, fixed_scale(64))
        assert q8.min() >= -127 and q8.max() <= 127
        # a saturated one-hot row must hit exactly +-127
        hot = np.zeros((1, 64), np.float32)
        hot[0, 5] = 1.0
        assert quantize_rows(hot, fixed_scale(64))[0, 5] == 127

    def test_rescore_topk_exactness_and_empty_slots(self):
        c = _unit((50, 32), 4)
        q = _unit((2, 32), 5)
        pool = np.array([[3, 7, -1, 12], [1, -1, -1, 2]], np.int64)
        s, i = rescore_topk(q, pool, c, 3)
        for qi in range(2):
            rows = [r for r in pool[qi] if r >= 0]
            want = sorted(((float(q[qi] @ c[r]), r) for r in rows),
                          reverse=True)[:3]
            got = [(float(s[qi, j]), int(i[qi, j]))
                   for j in range(3) if np.isfinite(s[qi, j])]
            assert [r for _, r in want] == [r for _, r in got]
            np.testing.assert_allclose([x for x, _ in want],
                                       [x for x, _ in got],
                                       rtol=1e-5, atol=1e-6)
        assert i[1, 2] == -1 and np.isneginf(s[1, 2])

    def test_f32rows_passthrough_and_stats(self):
        c = _unit((100, 16), 6)
        fetches = []

        def fetch(rows):
            fetches.append(len(rows))
            return c[rows]

        src = F32Rows(fetch, 16)
        np.testing.assert_array_equal(src.get(np.array([1, 2, 3])),
                                      c[[1, 2, 3]])
        np.testing.assert_array_equal(src.get(np.array([6]))[0], c[6])
        assert src.rows_read == 4 and fetches == [3, 1]
        assert src.nbytes() == 0               # page cache, not resident

    def test_append_only_f32_file(self, tmp_path):
        f = AppendOnlyF32File(str(tmp_path / "spill.bin"), 8)
        a, b = _unit((5, 8), 7), _unit((3, 8), 8)
        f.reset(a)
        f.append(b)
        got = f.fetch(np.array([0, 4, 6]))
        np.testing.assert_array_equal(got[0], a[0])
        np.testing.assert_array_equal(got[1], a[4])
        np.testing.assert_array_equal(got[2], b[1])
        f.reset(b)                              # pure cache: rewrite
        np.testing.assert_array_equal(f.fetch(np.array([2]))[0], b[2])


# ---------------------------------------------------------------------------
# segment persistence round-trip
# ---------------------------------------------------------------------------
class TestSegmentRoundTrip:
    def _seg(self, n, root, quantized, ivf_min_rows=1024):
        emb = _unit((n, 48), n)
        seg = Segment("00000001", emb, np.arange(n), np.arange(n),
                      [f"c{i}" for i in range(n)],
                      [f"d{i}" for i in range(n)],
                      [f"t{i}" for i in range(n)],
                      ivf_min_rows=ivf_min_rows, quantized=quantized)
        name, sha = seg.save(root)
        return seg, emb, name, sha

    @pytest.mark.parametrize("n,ivf_min", [(64, 1024), (2000, 1024)])
    def test_save_load_bit_stable(self, tmp_path, n, ivf_min):
        """quantize -> save -> load -> dequantize is bit-identical: the
        persisted q8 + scale ARE the quantization, never recomputed."""
        root = str(tmp_path)
        seg, emb, name, sha = self._seg(n, root, True, ivf_min)
        loaded = Segment.load(root, name, sha, ivf_min_rows=ivf_min)
        assert loaded.quantized and loaded.emb is None
        np.testing.assert_array_equal(loaded.q8, seg.q8)
        np.testing.assert_array_equal(loaded.scale, seg.scale)
        np.testing.assert_array_equal(dequantize(loaded.q8, loaded.scale),
                                      dequantize(seg.q8, seg.scale))
        # exact fp32 rows come back byte-identical through the sidecar
        rows = np.array([0, n // 2, n - 1])
        np.testing.assert_array_equal(loaded.fetch_f32(rows), emb[rows])

    def test_release_f32_shrinks_resident_bytes(self, tmp_path):
        root = str(tmp_path)
        seg, emb, _, _ = self._seg(256, root, True)
        before = seg.emb_nbytes()
        assert seg.release_f32()
        after = seg.emb_nbytes()
        assert after < before / 3              # fp32 dropped, int8 kept
        np.testing.assert_array_equal(seg.fetch_f32(np.array([7])), emb[7:8])

    def test_corrupt_sidecar_detected(self, tmp_path):
        root = str(tmp_path)
        seg, _, name, sha = self._seg(64, root, True)
        with open(os.path.join(root, seg.f32_filename()), "r+b") as f:
            f.seek(100)
            f.write(b"\xff\xff\xff\xff")
        with pytest.raises(IOError):
            Segment.load(root, name, sha)

    def test_fp32_format_still_loads(self, tmp_path):
        root = str(tmp_path)
        seg, emb, name, sha = self._seg(64, root, False)
        loaded = Segment.load(root, name, sha)
        assert not loaded.quantized
        np.testing.assert_array_equal(loaded.emb, emb)


# ---------------------------------------------------------------------------
# recall gates: quantized vs the fp32 oracle
# ---------------------------------------------------------------------------
class TestRecallGates:
    def _recall(self, res_a, res_b, k):
        vals = []
        for ra, rb in zip(res_a, res_b):
            ids_a = {r.chunk_id for r in ra}
            ids_b = {r.chunk_id for r in rb}
            vals.append(len(ids_a & ids_b) / max(len(ids_a), 1))
        return float(np.mean(vals)) if vals else 1.0

    def test_fused_and_ivf_recall(self):
        """Hot-tier paths: fused memtable+small segments AND IVF
        segments, quantized vs fp32, recall@10 >= 0.99."""
        rs = _records(6000, seed=1)
        q = _unit((16, 64), 9)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            a = SegmentedIndex(64, mem_capacity=512, root=r1,
                               ivf_min_rows=400)
            b = SegmentedIndex(64, mem_capacity=512, root=r2,
                               ivf_min_rows=400, quantized=True)
            a.insert(rs)
            b.insert(rs)
            assert b.validate_authority()
            ra, rb = a.search(q, k=10), b.search(q, k=10)
            assert self._recall(ra, rb, 10) >= 0.99
            # exact rescore: scores of shared winners match fp32 bitwise-
            # close (same fp32 dot, possibly different summation shape)
            for row_a, row_b in zip(ra, rb):
                sa = {r.chunk_id: r.score for r in row_a}
                for r in row_b:
                    if r.chunk_id in sa:
                        assert abs(r.score - sa[r.chunk_id]) < 1e-4

    def test_temporal_recall_point_and_window(self):
        corpus = generate_corpus(n_docs=10, n_versions=4, seed=2)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            fp = LiveVectorLake(r1, dim=64)
            qz = LiveVectorLake(r2, dim=64, quantized=True)
            for v in range(4):
                for d in corpus.doc_ids():
                    for store in (fp, qz):
                        store.ingest(d, corpus.versions[v][d],
                                     ts=corpus.timestamps[v])
            queries = [f"{f.name} units recorded"
                       for f in list(corpus.facts)[:8]]
            ts = int((corpus.timestamps[1] + corpus.timestamps[2]) // 2)
            w = (int(corpus.timestamps[1]), int(corpus.timestamps[3]))
            at_a = fp.query_batch(queries, k=10, at=ts)
            at_b = qz.query_batch(queries, k=10, at=ts)
            assert self._recall(at_a, at_b, 10) >= 0.99
            for row in at_b:
                qz.temporal.assert_no_leakage(row, ts)
            w_a = fp.query_batch(queries, k=10, window=w)
            w_b = qz.query_batch(queries, k=10, window=w)
            assert self._recall(w_a, w_b, 10) >= 0.99
            for row in w_b:
                qz.temporal.assert_no_window_leakage(row, *w)

    def test_quantized_resident_history_survives_restart(self):
        """Checkpoint sidecar round-trip: a reopened quantized store
        seeds its resident int8 history from the persisted checkpoint
        columns BIT-identically (no re-quantization drift) and serves
        the same temporal results."""
        corpus = generate_corpus(n_docs=6, n_versions=4, seed=3)
        with tempfile.TemporaryDirectory() as root:
            qz = LiveVectorLake(root, dim=64, quantized=True,
                                cold_checkpoint_interval=1)
            for v in range(4):
                for d in corpus.doc_ids():
                    qz.ingest(d, corpus.versions[v][d],
                              ts=corpus.timestamps[v])
            queries = [f"{f.name} units recorded"
                       for f in list(corpus.facts)[:4]]
            ts = int(corpus.timestamps[2]) + 1
            before = qz.query_batch(queries, k=5, at=ts)
            res1 = qz.temporal._resident_history()
            q8_before = res1.emb[:res1.n].copy()

            qz2 = LiveVectorLake(root, dim=64, quantized=True,
                                 cold_checkpoint_interval=1)
            after = qz2.query_batch(queries, k=5, at=ts)
            res2 = qz2.temporal._resident_history()
            np.testing.assert_array_equal(res2.emb[:res2.n], q8_before)
            assert [[(r.chunk_id, round(r.score, 5)) for r in row]
                    for row in before] == \
                   [[(r.chunk_id, round(r.score, 5)) for r in row]
                    for row in after]


# ---------------------------------------------------------------------------
# quantized write-path behavior (mirror, merge, delete)
# ---------------------------------------------------------------------------
class TestQuantizedWritePath:
    def test_mirror_keeps_fused_q8_in_sync(self):
        """Overwriting a memtable key must update the fused int8 block
        copy, not just the fp32 slot array."""
        with tempfile.TemporaryDirectory() as root:
            idx = SegmentedIndex(32, mem_capacity=8, root=root,
                                 ivf_min_rows=10_000, quantized=True)
            idx.insert(_records(20, d=32, seed=4, docs=20))  # seals: smalls
            assert idx._catalog().mirrored
            target = _unit((1, 32), 99)[0]
            rec = ChunkRecord(chunk_id="new", doc_id="d0", position=0,
                              valid_from=99, text="new",
                              embedding=target)
            idx.insert([rec])
            got = idx.search(target[None], k=1)[0][0]
            assert got.chunk_id == "new"
            assert idx.validate_authority()

    def test_merge_requantizes_from_exact_f32(self):
        """Compaction pulls victim rows through fetch_f32 (sidecar), so
        merged segments re-quantize from EXACT fp32 — error never
        compounds across merge generations."""
        with tempfile.TemporaryDirectory() as root:
            idx = SegmentedIndex(32, mem_capacity=64, root=root,
                                 ivf_min_rows=100_000, fanout=2,
                                 quantized=True)
            rs = _records(640, d=32, seed=5, docs=640)
            idx.insert(rs)
            assert idx.cstats.merges > 0
            emb = {r.chunk_id: r.embedding for r in rs}
            for seg in idx.segments.values():
                rows = np.arange(len(seg))
                f32 = seg.fetch_f32(rows)
                for i in rows:
                    np.testing.assert_array_equal(f32[i],
                                                  emb[seg.chunk_ids[i]])
                np.testing.assert_array_equal(
                    seg.q8, quantize_rows(f32, seg.scale))

    def test_scan_accounting_consistent_between_fused_and_ivf(self):
        """ISSUE 5 satellite: the fused block reads each row once per
        BATCH (so its per-query amortized fraction halves at nq=2); IVF
        member scans are per-query (fraction independent of nq)."""
        # fused-only index
        idx = SegmentedIndex(32, mem_capacity=128)
        idx.insert(_records(100, d=32, seed=6, docs=100))
        q = _unit((2, 32), 7)
        idx.search(q[:1], k=3)
        f1 = idx.stats()["avg_fraction_scanned"]
        assert f1 == pytest.approx(1.0)        # nq=1: whole block / rows
        idx._scan_scanned = idx._scan_denom = 0
        idx.search(q, k=3)
        f2 = idx.stats()["avg_fraction_scanned"]
        assert f2 == pytest.approx(0.5)        # one batch read / 2 queries
        # IVF-only index: per-query fraction must NOT depend on nq
        idx2 = SegmentedIndex(32, mem_capacity=256, ivf_min_rows=200)
        idx2.insert(_records(2000, d=32, seed=8, docs=2000))
        idx2.seal()
        idx2._scan_scanned = idx2._scan_denom = 0
        idx2.search(q[:1], k=3)
        g1 = idx2.stats()["avg_fraction_scanned"]
        idx2._scan_scanned = idx2._scan_denom = 0
        idx2.search(np.repeat(q[:1], 2, axis=0), k=3)
        g2 = idx2.stats()["avg_fraction_scanned"]
        assert g1 == pytest.approx(g2, rel=0.05)

    def test_ivf_batch_equals_sequential_under_score_ties(self):
        """Massive duplicate embeddings force int8 score ties across the
        pool cut; the union-batched IVF scan must still return results
        BIT-identical to each query running alone (the boundary-tie
        repair is layout-independent)."""
        base = _unit((60, 32), 20)
        emb = np.concatenate([np.repeat(base[:4], 40, axis=0), base[4:]])
        rs = [ChunkRecord(chunk_id=f"t{i}", doc_id=f"d{i}", position=0,
                          valid_from=1 + i, text=f"t{i}", embedding=emb[i])
              for i in range(emb.shape[0])]
        idx = SegmentedIndex(32, mem_capacity=64, ivf_min_rows=100,
                             quantized=True)
        idx.insert(rs)
        idx.seal()
        q = np.concatenate([base[:2] + 1e-3, _unit((2, 32), 21)])
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        batched = idx.search(q, k=8)
        for qi in range(q.shape[0]):
            solo = idx.search(q[qi][None], k=8)[0]
            assert [(r.chunk_id, r.score) for r in solo] == \
                   [(r.chunk_id, r.score) for r in batched[qi]], qi

    def test_ivf_min_rows_drift_on_reopen(self):
        """Config drift: quantized segments reopened under a RAISED
        ivf_min_rows lose their IVF and fall to the solo scan path
        (their data scale cannot join the fused block); under a LOWERED
        one, k-means rebuilds from the fp32 sidecar. Both must serve
        with recall, not crash or silently mis-scale."""
        rs = _records(2000, d=32, seed=11, docs=2000)
        q = _unit((4, 32), 12)
        with tempfile.TemporaryDirectory() as root:
            idx = SegmentedIndex(32, mem_capacity=256, root=root,
                                 ivf_min_rows=400, quantized=True)
            idx.insert(rs)
            want = [{r.chunk_id for r in row} for row in idx.search(q, k=10)]
            for new_min in (100_000, 50):       # raise, then lower
                idx2 = SegmentedIndex(32, mem_capacity=256, root=root,
                                      ivf_min_rows=new_min, quantized=True)
                idx2.rebuild(rs)
                got = idx2.search(q, k=10)
                rec = np.mean([len(want[i] & {r.chunk_id for r in got[i]})
                               / 10 for i in range(4)])
                assert rec >= 0.9, (new_min, rec)
                assert idx2.validate_authority()

    def test_store_quantized_flag_persists_across_reopen(self):
        """Reopening with the default (quantized=None) must adopt the
        persisted format — never silently materialize fp32 back."""
        with tempfile.TemporaryDirectory() as root:
            qz = LiveVectorLake(root, dim=32, quantized=True)
            qz.ingest("d0", "alpha metrics chunk.\n\nbeta backups chunk.")
            re = LiveVectorLake(root, dim=32)           # flag omitted
            assert re.quantized is True
            assert re.hot.index.quantized is True
            # explicit override still wins (and re-persists)
            fp = LiveVectorLake(root, dim=32, quantized=False)
            assert fp.quantized is False
            assert LiveVectorLake(root, dim=32).quantized is False

    def test_resident_bytes_reduction(self):
        """The headline claim at index level: quantized resident
        embedding bytes ~4x below fp32 once segments dominate."""
        rs = _records(20_000, d=64, seed=10, docs=20_000)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            a = SegmentedIndex(64, mem_capacity=1024, root=r1,
                               ivf_min_rows=512)
            b = SegmentedIndex(64, mem_capacity=1024, root=r2,
                               ivf_min_rows=512, quantized=True)
            a.insert(rs)
            b.insert(rs)
            ratio = a.nbytes() / b.nbytes()
            assert ratio >= 3.0, ratio
