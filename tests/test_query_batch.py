"""Batched query engine tests (DESIGN.md §8): batch/single parity across
temporal intents and index states, the vectorized merge vs the tuple-sort
reference, authority-array invariants, and serving-layer coalescing."""
import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.core.types import ChunkRecord
from repro.index.lsm import SegmentedIndex, merge_topk_candidates

T1, T2, T3 = 1_000_000, 2_000_000, 3_000_000

DOCS = {
    "runbook": [
        "The SLA is four hours.\n\nBackups run nightly.\n\nReviews happen quarterly.",
        "The SLA is two hours.\n\nBackups run nightly.\n\nReviews happen quarterly.",
        "The SLA is two hours.\n\nBackups run hourly.\n\nReviews happen quarterly."
        "\n\nOn-call covers weekends.",
    ],
    "policy": [
        "Passwords rotate yearly.\n\nMFA is optional.",
        "Passwords rotate quarterly.\n\nMFA is mandatory.",
        "Passwords rotate quarterly.\n\nMFA is mandatory.\n\nHardware keys are issued.",
    ],
}

QUERIES = ["incident response SLA", "backup schedule", "password rotation",
           "MFA policy", "hardware keys", "review cadence"]


def _mk_records(vecs, start=0, doc="d", ts=1):
    return [ChunkRecord(chunk_id=f"c{start + i}", doc_id=doc,
                        position=start + i, valid_from=ts,
                        text=f"t{start + i}", embedding=vecs[i])
            for i in range(len(vecs))]


def _unit(rng, n, dim):
    v = rng.standard_normal((n, dim)).astype(np.float32)
    return v / np.linalg.norm(v, axis=1, keepdims=True)


def _assert_parity(store, queries, k=3, **kw):
    batch = store.query_batch(queries, k=k, **kw)
    seq = [store.query(t, k=k, **kw) for t in queries]
    assert batch == seq     # dataclass equality: every field, exact score


class TestIndexBatchParity:
    def test_batch_equals_sequential_with_tombstones_and_segments(self):
        rng = np.random.default_rng(0)
        dim = 64
        idx = SegmentedIndex(dim, mem_capacity=512, nprobe=8,
                             ivf_min_rows=1024)
        v = _unit(rng, 6000, dim)
        idx.insert(_mk_records(v))
        idx.delete([("d", i) for i in range(0, 6000, 13)])   # tombstones
        idx.insert(_mk_records(_unit(rng, 200, dim), start=100))  # shadows
        st = idx.stats()
        assert st["segments"] > 1 and st["tombstones"] > 0
        assert st["partitioned_segments"] >= 1        # IVF + small mixed
        q = (v[rng.choice(6000, 16)]
             + 0.02 * rng.standard_normal((16, dim))).astype(np.float32)
        batch = idx.search(q, k=10)
        for i in range(len(q)):
            assert idx.search(q[i], k=10)[0] == batch[i]

    def test_authority_arrays_match_by_key(self):
        rng = np.random.default_rng(1)
        idx = SegmentedIndex(32, mem_capacity=64, ivf_min_rows=128)
        idx.insert(_mk_records(_unit(rng, 500, 32)))
        idx.delete([("d", i) for i in range(0, 500, 7)])
        idx.insert(_mk_records(_unit(rng, 50, 32), start=10))
        assert idx.validate_authority()

    def test_empty_and_tiny_batches(self):
        idx = SegmentedIndex(16, mem_capacity=8)
        assert idx.search(np.zeros((3, 16), np.float32), k=5) == [[], [], []]
        rng = np.random.default_rng(2)
        idx.insert(_mk_records(_unit(rng, 3, 16)))
        res = idx.search(_unit(rng, 5, 16), k=7)
        assert len(res) == 5
        assert all(len(r) == 3 for r in res)          # k > corpus size


class TestVectorizedMerge:
    @staticmethod
    def _merge_ref(scores, gids, authority, k):
        """The old tuple-sort merge: stable sort by -score (ties keep
        candidate order), drop non-authoritative rows, take k."""
        out = []
        for qi in range(scores.shape[0]):
            cands = [(float(scores[qi, j]), int(gids[qi, j]))
                     for j in range(scores.shape[1])]
            picked = []
            for s, g in sorted(cands, key=lambda t: -t[0]):
                if len(picked) == k:
                    break
                if g < 0 or not np.isfinite(s) or not authority[g]:
                    continue
                picked.append((np.float32(s), g))
            out.append(picked)
        return out

    def test_matches_tuple_sort_reference_randomized(self):
        rng = np.random.default_rng(3)
        for trial in range(50):
            nq = int(rng.integers(1, 6))
            w = int(rng.integers(1, 40))
            n_rows = int(rng.integers(1, 60))
            k = int(rng.integers(1, 12))
            # coarse score grid => plenty of exact ties
            scores = rng.integers(-3, 4, (nq, w)).astype(np.float32) / 2.0
            scores[rng.random((nq, w)) < 0.15] = -np.inf
            gids = rng.integers(-1, n_rows, (nq, w))
            authority = rng.random(n_rows) < 0.7
            top_s, top_g = merge_topk_candidates(scores, gids, authority, k)
            ref = self._merge_ref(scores, gids, authority, k)
            for qi in range(nq):
                got = [(top_s[qi, j], int(top_g[qi, j]))
                       for j in range(k) if top_g[qi, j] >= 0]
                assert got == ref[qi], (trial, qi)


class TestStoreBatchParity:
    @pytest.fixture()
    def store(self, tmp_path):
        store = LiveVectorLake(str(tmp_path), dim=96, hot_capacity=4)
        for v, ts in enumerate((T1, T2, T3)):
            for d, versions in DOCS.items():
                store.ingest(d, versions[v], ts=ts)
        return store

    def test_current_parity(self, store):
        _assert_parity(store, QUERIES)

    def test_historical_parity(self, store):
        _assert_parity(store, QUERIES, at=T2 + 500)
        for r in store.query_batch(QUERIES, k=3, at=T1 + 500):
            for hit in r:
                assert hit.valid_from <= T1 + 500 < hit.valid_to

    def test_comparative_parity(self, store):
        _assert_parity(store, QUERIES, window=(T1 + 500, T2 + 500))

    def test_mixed_intent_batch(self, store):
        """One batch containing all three intents (parsed from text)
        routes each query to its tier and returns in input order."""
        mixed = ["incident response SLA",
                 "backup schedule as of 1970-01-01",
                 "MFA policy between 1970-01-01 and 1970-01-02",
                 "password rotation"]
        batch = store.query_batch(mixed, k=3)
        seq = [store.query(t, k=3) for t in mixed]
        assert batch == seq
        assert all(r.tier == "hot" for r in batch[0])
        assert all(r.tier == "cold" for r in batch[2])

    def test_mid_stream_parity_with_tombstones_and_seal(self, store):
        """Parity holds right after updates that tombstone segment rows
        and force a seal mid-stream (hot_capacity=4 seals constantly)."""
        _assert_parity(store, QUERIES)
        store.ingest("runbook", DOCS["runbook"][0], ts=T3 + 1)  # revert
        assert store.hot.index.stats()["segments"] > 0
        _assert_parity(store, QUERIES)
        _assert_parity(store, QUERIES, at=T2 + 500)
        assert store.hot.index.validate_authority()

    def test_batch_is_order_independent(self, store):
        fwd = store.query_batch(QUERIES, k=3)
        rev = store.query_batch(QUERIES[::-1], k=3)
        assert fwd == rev[::-1]

    def test_empty_batch(self, store):
        assert store.query_batch([]) == []

    def test_resident_history_incremental_and_correct(self, store):
        """The fused path seeds its resident full-history arrays ONCE and
        advances them incrementally on commit — repeated point-in-time
        queries and post-ingest queries never re-fold the log."""
        ts = T2 + 500
        store.query_batch(QUERIES, k=3, at=ts)
        assert store.temporal.resident_builds == 1
        d0 = store.temporal.fused_dispatches
        store.query_batch(QUERIES, k=3, at=ts)
        assert store.temporal.fused_dispatches == d0 + 1
        assert store.temporal.resident_builds == 1    # no re-seed

        n0 = store.temporal._resident.n
        store.ingest("policy", DOCS["policy"][0], ts=T3 + 7)
        # ingest advanced the resident columns in place (no rebuild)
        assert store.temporal.resident_builds == 1
        assert store.temporal._resident.n > n0
        _assert_parity(store, QUERIES, at=ts)         # still correct
        # and the resident columns equal the full-history fold exactly
        snap = store.cold.snapshot(include_closed=True, from_scratch=True)
        res = store.temporal._resident
        assert res.n == len(snap)
        emb, vf, vt = res.views()
        np.testing.assert_array_equal(vf, snap.valid_from)
        np.testing.assert_array_equal(vt, snap.valid_to)
        np.testing.assert_array_equal(emb, snap.embeddings)
        assert res.chunk_ids == snap.chunk_ids

    def test_oracle_path_matches_fused(self, tmp_path):
        """The paper-faithful NumPy fold path (temporal_fused=False) and
        the fused kernel path return the same records and scores."""
        fused = LiveVectorLake(str(tmp_path / "f"), dim=96)
        oracle = LiveVectorLake(str(tmp_path / "o"), dim=96,
                                temporal_fused=False)
        for s in (fused, oracle):
            for v, ts in enumerate((T1, T2, T3)):
                for d, versions in DOCS.items():
                    s.ingest(d, versions[v], ts=ts)
        for at in (T1 + 500, T2 + 500, T2):           # incl boundary instant
            rf = fused.query_batch(QUERIES, k=3, at=at)
            ro = oracle.query_batch(QUERIES, k=3, at=at)
            for a, b in zip(rf, ro):
                assert [(r.chunk_id, r.score) for r in a] == \
                    [(r.chunk_id, r.score) for r in b]


class TestServingCoalescing:
    def test_query_batcher_coalesces_current(self, tmp_path):
        store = LiveVectorLake(str(tmp_path), dim=64)
        for d, versions in DOCS.items():
            store.ingest(d, versions[-1], ts=T1)
        b = store.query_batcher(k=3, max_batch=8)
        reqs = [b.submit(q) for q in QUERIES]
        b.drain()
        assert b.stats["batches"] == 1                # ONE hot-tier batch
        assert b.stats["mean_batch_size"] == len(QUERIES)
        assert [r.result for r in reqs] == \
            [store.query(q, k=3) for q in QUERIES]

    def test_query_batcher_buckets_by_intent(self, tmp_path):
        store = LiveVectorLake(str(tmp_path), dim=64)
        for v, ts in enumerate((T1, T2)):
            for d, versions in DOCS.items():
                store.ingest(d, versions[v], ts=ts)
        b = store.query_batcher(k=3, max_batch=8)
        reqs = [b.submit("incident response SLA"),
                b.submit(("backup schedule", T1 + 500, None)),
                b.submit("MFA policy"),
                b.submit(("password rotation", T1 + 500, None))]
        b.drain()
        assert b.stats["batches"] == 2                # current + historical
        assert reqs[0].result == store.query("incident response SLA", k=3)
        assert reqs[1].result == store.query("backup schedule", k=3,
                                             at=T1 + 500)

    def test_query_batcher_mixed_explicit_and_parsed_intent(self, tmp_path):
        """A text-parsed 'as of' request and an explicit-at request with
        the SAME instant share a bucket AND both hit the snapshot — the
        explicit request must not be re-classified as CURRENT when
        coalesced behind the parsed one (regression)."""
        store = LiveVectorLake(str(tmp_path), dim=64)
        for v, ts in enumerate((T1, T2)):
            for d, versions in DOCS.items():
                store.ingest(d, versions[v], ts=ts)
        from repro.core.temporal import _iso_to_us
        iso_ts = _iso_to_us("1970-01-01")
        b = store.query_batcher(k=3, max_batch=8)
        r_parsed = b.submit("backup schedule as of 1970-01-01")
        r_explicit = b.submit(("MFA policy", iso_ts, None))
        b.drain()
        assert b.stats["batches"] == 1                # same intent bucket
        assert r_explicit.result == store.query("MFA policy", k=3,
                                                at=iso_ts)
        assert r_parsed.result == store.query(
            "backup schedule as of 1970-01-01", k=3)

    def test_rag_engine_answer_batch(self, tmp_path):
        from repro.models.transformer import TransformerConfig
        from repro.serve.engine import RAGEngine

        store = LiveVectorLake(str(tmp_path), dim=48)
        for d, versions in DOCS.items():
            store.ingest(d, versions[-1], ts=T1)
        cfg = TransformerConfig(name="tiny", vocab=128, d_model=32,
                                n_layers=2, n_heads=4, n_kv=2, d_head=8,
                                d_ff=64, act="swiglu", remat=False)
        eng = RAGEngine(store, cfg, max_prompt=64, retrieval_k=2)
        qs = ["incident response SLA", "MFA policy"]
        outs = eng.answer_batch(qs, max_new_tokens=2)
        assert eng.retrieval_batcher.stats["batches"] == 1
        for q, out in zip(qs, outs):
            solo = eng.answer(q, k=2, max_new_tokens=2)
            assert out.retrieved == solo.retrieved    # bit-identical ctx
            assert out.token_ids == solo.token_ids
