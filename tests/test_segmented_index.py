"""Segmented streaming index: memtable/seal/tombstone semantics,
deterministic compaction, manifest crash recovery (fault-injected
mid-seal and mid-compaction), and the IVF recall regression bar
(DESIGN.md §7)."""
import glob
import os

import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.core.types import ChunkRecord
from repro.index.compaction import SizeTieredCompactor, _tier
from repro.index.lsm import CompactionInterrupted, SegmentedIndex
from repro.index.manifest import Manifest

DIM = 32


def _vec(i, dim=DIM):
    rng = np.random.default_rng(i)
    v = rng.standard_normal(dim).astype(np.float32)
    return v / np.linalg.norm(v)


def _rec(pos, doc="d", seed=None, text=None):
    return ChunkRecord(chunk_id=f"h{doc}{pos}s{seed}", doc_id=doc,
                       position=pos, valid_from=pos + 1,
                       text=text or f"t{pos}",
                       embedding=_vec(seed if seed is not None else pos))


class TestMemtableSeal:
    def test_seal_moves_rows_to_segment(self):
        idx = SegmentedIndex(DIM, mem_capacity=8, ivf_min_rows=10**9)
        idx.insert([_rec(i) for i in range(20)])
        assert len(idx) == 20
        assert len(idx.segments) >= 1
        assert sum(len(s) for s in idx.segments.values()) + len(idx.mem) == 20
        # every key resolves and searches still find the sealed rows
        for pos in (0, 7, 13, 19):
            res = idx.search(_vec(pos), k=1)[0]
            assert res and res[0].position == pos

    def test_search_matches_flat_exact_scan(self):
        idx = SegmentedIndex(DIM, mem_capacity=16, ivf_min_rows=10**9)
        recs = [_rec(i) for i in range(100)]
        idx.insert(recs)
        mat = np.stack([r.embedding for r in recs])
        q = _vec(1234)
        exact = np.argsort(-(mat @ q))[:5]
        got = [r.position for r in idx.search(q, k=5)[0]]
        assert got == [recs[j].position for j in exact]

    def test_overwrite_in_memtable_is_in_place(self):
        idx = SegmentedIndex(DIM, mem_capacity=8)
        idx.insert([_rec(0, seed=1)])
        idx.insert([_rec(0, seed=2, text="new")])
        assert len(idx) == 1 and len(idx.mem) == 1
        assert idx.search(_vec(2), k=1)[0][0].text == "new"


class TestTombstones:
    def test_delete_across_seal_never_returned(self):
        idx = SegmentedIndex(DIM, mem_capacity=4, ivf_min_rows=10**9)
        idx.insert([_rec(i) for i in range(12)])
        assert idx.delete([("d", 2)]) == 1
        for r in idx.search(_vec(2), k=12)[0]:
            assert r.position != 2
        assert len(idx) == 11

    def test_update_shadows_segment_row(self):
        idx = SegmentedIndex(DIM, mem_capacity=4, ivf_min_rows=10**9)
        idx.insert([_rec(i) for i in range(8)])       # pos 0 sealed
        idx.insert([_rec(0, seed=777, text="newest")])
        res = idx.search(_vec(777), k=8)[0]
        hits = [r for r in res if r.position == 0]
        assert len(hits) == 1 and hits[0].text == "newest"

    def test_delete_alone_triggers_tombstone_purge(self):
        """A delete-heavy stream with NO subsequent inserts must still
        reclaim majority-dead segments."""
        idx = SegmentedIndex(DIM, mem_capacity=64, ivf_min_rows=10**9)
        idx.compactor.purge_min_rows = 32
        idx.insert([_rec(i) for i in range(64)])
        idx.seal()
        assert idx.delete([("d", i) for i in range(40)]) == 40
        assert idx.cstats.tombstones_purged >= 40
        assert sum(len(s) - s.n_alive for s in idx.segments.values()) == 0
        assert len(idx) == 24


class TestCompactionPolicy:
    def test_tiering(self):
        assert _tier(0) == 0 and _tier(3) == 0
        assert _tier(4) == 1 and _tier(15) == 1
        assert _tier(16) == 2 and _tier(4096) == 6
        # tier base follows fanout: merging `fanout` same-tier segments
        # must always land in a strictly higher tier
        for fanout in (2, 3, 4):
            for n in (1, 2, 5, 9, 64):
                assert _tier(fanout * n, fanout) > _tier(n, fanout)

    def test_size_tiered_merge_is_deterministic(self):
        a = SegmentedIndex(DIM, mem_capacity=4, ivf_min_rows=10**9)
        b = SegmentedIndex(DIM, mem_capacity=4, ivf_min_rows=10**9)
        recs = [_rec(i) for i in range(50)]
        a.insert(recs)
        for r in recs:
            b.insert([r])                      # different batching
        layout = lambda ix: sorted((len(s), s.n_alive)
                                   for s in ix.segments.values())
        assert layout(a) == layout(b)
        assert sorted(a._by_key) == sorted(b._by_key)

    def test_fanout_merge_triggers(self):
        idx = SegmentedIndex(DIM, mem_capacity=4, ivf_min_rows=10**9,
                             fanout=4)
        # seal is lazy (fires on the insert AFTER the memtable fills), so
        # 20 rows -> 4 sealed segments of 4 -> one fanout merge
        idx.insert([_rec(i) for i in range(20)])
        assert idx.cstats.merges >= 1
        assert idx.cstats.write_amplification > 1.0
        comp = SizeTieredCompactor(fanout=4)
        assert comp.pick(list(idx.segments.values())) == []


class TestRecallRegression:
    def test_ivf_recall_at_k_10k_corpus(self):
        """recall@10 >= 0.95 at nprobe=8 on a clustered 10k corpus while
        scanning sub-linearly — the DESIGN.md §7 acceptance bar."""
        rng = np.random.default_rng(0)
        n, d = 10_000, 64
        centers = rng.standard_normal((48, d)).astype(np.float32)
        corpus = centers[rng.integers(0, 48, n)] + \
            0.3 * rng.standard_normal((n, d)).astype(np.float32)
        corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
        idx = SegmentedIndex(d, mem_capacity=2048, nprobe=8,
                             ivf_min_rows=1024)
        idx.insert([ChunkRecord(chunk_id=f"c{i}", doc_id="v", position=i,
                                valid_from=1, text="", embedding=corpus[i])
                    for i in range(n)])
        assert any(s.ivf is not None for s in idx.segments.values())
        q = corpus[rng.choice(n, 25)] + \
            0.05 * rng.standard_normal((25, d)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        exact = np.argsort(-(q @ corpus.T), axis=1)[:, :10]
        res = idx.search(q, k=10)
        hits = sum(len({r.position for r in res[i]} & set(exact[i]))
                   for i in range(25))
        assert hits / 250 >= 0.95
        assert idx.stats()["avg_fraction_scanned"] < 0.5

    def test_ivf_state_roundtrips_without_kmeans(self, tmp_path, monkeypatch):
        """Segment save/load must reuse the persisted partitioning: same
        search results, and IVFIndex.build (k-means) never runs on load."""
        from repro.core import ivf as ivf_mod
        from repro.index.segment import Segment
        rng = np.random.default_rng(3)
        emb = rng.standard_normal((2048, 16)).astype(np.float32)
        emb /= np.linalg.norm(emb, axis=1, keepdims=True)
        seg = Segment("00000001", emb, np.ones(2048, np.int64),
                      np.arange(2048), [f"c{i}" for i in range(2048)],
                      ["d"] * 2048, [""] * 2048, ivf_min_rows=1024)
        assert seg.ivf is not None
        seg.save(str(tmp_path))
        monkeypatch.setattr(
            ivf_mod.IVFIndex, "build",
            lambda self, v: pytest.fail("k-means re-ran on load"))
        seg2 = Segment.load(str(tmp_path), seg.filename(),
                            ivf_min_rows=1024)
        assert seg2.ivf is not None
        q = emb[:4]
        s1, i1, _ = seg.search(q, k=5)
        s2, i2, _ = seg2.search(q, k=5)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_allclose(s1, s2, rtol=1e-6)


DOC = "\n\n".join(f"paragraph {{i}} number {j} words" for j in range(3))


def _fill(store, lo, hi, tag="d"):
    for i in range(lo, hi):
        store.ingest(f"{tag}{i}", DOC.format(i=i).replace("{i}", str(i)),
                     ts=(i + 1) * 1_000_000)


def _cold_keys(store):
    snap = store.cold.snapshot()
    return sorted((snap.doc_ids[i], int(snap.position[i]))
                  for i in range(len(snap)))


class TestCrashRecovery:
    def test_manifest_restore_skips_monolithic_insert(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        _fill(store, 0, 8)
        before = sorted(store.hot._by_key)
        store2 = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        assert sorted(store2.hot._by_key) == before
        rep = store2.recover()
        # the bulk came back from segments, not a monolithic re-insert
        assert rep["hot_restored_from_segments"] > 0
        assert rep["hot_delta_inserted"] < rep["hot_rebuilt"]

    @pytest.mark.parametrize("fail_at", ["seal:before_manifest",
                                         "seal:after_manifest",
                                         "merge:before_manifest",
                                         "merge:after_manifest"])
    def test_fault_injected_seal_and_compaction(self, tmp_path, fail_at):
        root = str(tmp_path / f"lvl-{fail_at.replace(':', '_')}")
        store = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        _fill(store, 0, 6)
        store.hot.index.fail_at = fail_at
        with pytest.raises(CompactionInterrupted):
            _fill(store, 6, 30, tag="e")
        # restart: manifest + WAL reconcile must yield exactly the cold
        # tier's active set, no pending transactions, queries consistent
        store2 = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        assert not store2.wal.pending()
        assert sorted(store2.hot._by_key) == _cold_keys(store2)
        res = store2.query("paragraph 3 number 1 words", k=3)
        assert res and res[0].tier == "hot"

    def test_orphan_segments_cleaned_on_recover(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        _fill(store, 0, 8)
        hot_dir = os.path.join(root, "hot_index")
        orphan = os.path.join(hot_dir, "seg-99999999.npz")
        with open(orphan, "wb") as f:
            f.write(b"leftover from a crashed compaction")
        LiveVectorLake(root, dim=DIM, hot_capacity=4)
        assert not os.path.exists(orphan)

    def test_corrupt_segment_falls_back_to_full_rebuild(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        _fill(store, 0, 8)
        seg = glob.glob(os.path.join(root, "hot_index", "seg-*.npz"))[0]
        with open(seg, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))
        store2 = LiveVectorLake(root, dim=DIM, hot_capacity=4)
        assert sorted(store2.hot._by_key) == _cold_keys(store2)

    def test_manifest_atomic_commit_generation(self, tmp_path):
        m = Manifest(str(tmp_path / "idx"))
        assert m.load() is None
        g1 = m.commit([{"name": "seg-1.npz", "checksum": "x", "rows": 4}],
                      seq=1)
        g2 = m.commit([], seq=1)
        assert (g1, g2) == (1, 2)
        assert m.load()["generation"] == 2
        assert m.load()["segments"] == []


class TestHotTierClearReset:
    def test_clear_is_explicit_reset_not_reinit(self, tmp_path):
        """clear() must reset the engine through its own code path — the
        segmented index object survives (no silent identity swap) and the
        persisted manifest is emptied too."""
        store = LiveVectorLake(str(tmp_path / "lvl"), dim=DIM,
                               hot_capacity=4)
        _fill(store, 0, 6)
        engine = store.hot.index
        assert len(store.hot) > 0 and engine.segments
        store.hot.clear()
        assert store.hot.index is engine           # same engine object
        assert len(store.hot) == 0 and not engine.segments
        assert store.hot.capacity == 4
        m = engine.manifest.load()
        assert m is not None and m["segments"] == []
        assert not glob.glob(os.path.join(str(tmp_path / "lvl"),
                                          "hot_index", "seg-*.npz"))
