"""Batcher failure-path tests (serve/batcher.py): a batch whose
execution raises — e.g. a shard failing mid-gather in the fabric
planner — must complete ONLY its own requests with ``error`` set and
leave the coalescing queue drainable (no deadlock, no stranded
requests, later submits unaffected)."""
import tempfile

import numpy as np

from repro.serve.batcher import Batcher
from repro.shard import ShardFabric, ShardGatherError


class TestBatcherFailureIsolation:
    def test_failing_batch_fails_only_its_bucket(self):
        calls = []

        def run(payloads):
            calls.append(list(payloads))
            if any("boom" in p for p in payloads):
                raise RuntimeError("shard down")
            return [p.upper() for p in payloads]

        b = Batcher(run, max_batch=4,
                    bucket_fn=lambda p: "bad" if "boom" in p else "good")
        good = [b.submit(f"ok{i}") for i in range(3)]
        bad = [b.submit(f"boom{i}") for i in range(2)]
        more_good = [b.submit("late")]
        b.drain()
        assert not b._queue                      # drainable: queue empty
        for r in good + more_good:
            assert r.done and r.error is None
        assert more_good[0].result == "LATE"
        for r in bad:
            assert r.done and r.result is None
            assert isinstance(r.error, RuntimeError)
        assert b.stats["failed_batches"] == 1
        assert b.stats["batches"] == len(calls)

    def test_queue_survives_repeated_failures_and_recovers(self):
        state = {"fail": True}

        def run(payloads):
            if state["fail"]:
                raise ValueError("still down")
            return list(payloads)

        b = Batcher(run, max_batch=2)
        r1 = b.submit("a")
        b.drain()
        assert isinstance(r1.error, ValueError)
        state["fail"] = False                    # shard comes back
        r2 = b.submit("b")
        b.drain()
        assert r2.done and r2.error is None and r2.result == "b"

    def test_length_mismatch_is_an_error_not_a_hang(self):
        b = Batcher(lambda ps: ps[:-1], max_batch=4)
        reqs = [b.submit(i) for i in range(3)]
        b.drain()
        assert not b._queue
        for r in reqs:
            assert r.done and isinstance(r.error, RuntimeError)

    def test_shard_raising_mid_gather_through_fabric_batcher(self):
        """End to end: one shard dies; with R=1 the CURRENT bucket's
        batch fails with ShardGatherError, the temporal bucket that
        doesn't trip the fault still answers, and the queue drains."""
        with tempfile.TemporaryDirectory() as root:
            fab = ShardFabric(root, n_shards=3, dim=32, hot_capacity=512)
            ts = 0
            for i in range(6):
                ts += 1_000_000
                fab.ingest(f"doc{i}", f"alpha bravo {chr(97 + i)}\n\n"
                           f"carbon delta {chr(97 + i)}", ts=ts)
            dead = fab.ring.shards[0]
            orig = fab.lake(dead).query_batch

            def flaky(texts, **kw):
                if kw.get("at") is None:        # fail only CURRENT gathers
                    raise RuntimeError("shard down")
                return orig(texts, **kw)
            fab.lake(dead).query_batch = flaky

            b = fab.query_batcher(k=3)
            current = [b.submit("alpha bravo"), b.submit("carbon delta")]
            temporal = [b.submit(("alpha bravo", ts // 2, None))]
            b.drain()
            assert not b._queue
            for r in current:
                assert r.done and isinstance(r.error, ShardGatherError)
            assert temporal[0].done and temporal[0].error is None
            assert len(temporal[0].result) > 0
            for res in temporal[0].result:
                assert res.valid_from <= ts // 2 < res.valid_to
            # the fabric keeps serving new batches after the failure
            ok = [b.submit(("carbon delta", ts // 2, None))]
            b.drain()
            assert ok[0].error is None and len(ok[0].result) > 0

    def test_hedge_retry_failure_keeps_original_results(self):
        state = {"calls": 0}

        def run(payloads):
            state["calls"] += 1
            if state["calls"] == 3:              # only the hedge retry dies
                raise RuntimeError("hedge died")
            return list(payloads)

        b = Batcher(run, max_batch=2, hedge_factor=0.0)   # always hedge
        b.submit("x")
        b.drain()                                # establish EWMA
        r = b.submit("y")
        b.drain()
        assert r.done and r.error is None and r.result == "y"
        assert np.isfinite(b._lat_ewma)


class TestAdmissionControl:
    def test_reject_past_high_watermark_with_error(self):
        from repro.serve.batcher import AdmissionRejected
        b = Batcher(lambda ps: list(ps), max_batch=4, max_queue=3)
        admitted = [b.submit(i) for i in range(3)]
        shed = b.submit(99)
        # explicit rejection, not a silent drop: completed-with-error
        assert shed.done and isinstance(shed.error, AdmissionRejected)
        assert b.stats["rejected"] == 1
        b.drain()
        for r in admitted:
            assert r.done and r.error is None
        # queue drained => admission reopens
        again = b.submit(7)
        b.drain()
        assert again.error is None and again.result == 7

    def test_rejected_requests_never_counted_as_served(self):
        b = Batcher(lambda ps: list(ps), max_batch=4, max_queue=1)
        b.submit(1)
        b.submit(2)                       # shed
        b.drain()
        assert b.stats["requests"] == 1
        assert b.stats["rejected"] == 1


class TestDeadlines:
    def test_expired_in_queue_completes_with_deadline_error(self):
        import time
        from repro.serve.deadline import DeadlineExceeded
        b = Batcher(lambda ps: list(ps), max_batch=4)
        r_dead = b.submit("x", deadline_s=0.001)
        r_live = b.submit("y")
        time.sleep(0.01)                  # deadline passes while queued
        b.drain()
        assert r_dead.done and isinstance(r_dead.error, DeadlineExceeded)
        assert r_live.done and r_live.error is None
        assert b.stats["deadline_expired"] == 1

    def test_batch_runs_under_tightest_member_deadline(self):
        from repro.serve.deadline import deadline_at, remaining
        seen = {}

        def run(ps):
            seen["at"] = deadline_at()
            seen["remaining"] = remaining()
            return list(ps)

        b = Batcher(run, max_batch=4, default_deadline_s=10.0)
        b.submit("a")
        b.submit("b", deadline_s=0.5)     # the tight one
        b.drain()
        assert seen["at"] is not None
        assert seen["remaining"] < 1.0    # 0.5s member bounds the batch

    def test_run_raising_deadline_counts_and_isolates(self):
        from repro.serve.deadline import DeadlineExceeded

        def run(ps):
            raise DeadlineExceeded("downstream gave up")

        b = Batcher(run, max_batch=4)
        r = b.submit("x")
        b.drain()
        assert isinstance(r.error, DeadlineExceeded)
        assert b.stats["deadline_expired"] == 1
        assert b.stats["failed_batches"] == 1


class TestHedgeAccounting:
    def test_no_double_completion_or_double_count_when_hedge_wins(self):
        import time
        state = {"calls": 0}

        def run(payloads):
            state["calls"] += 1
            if state["calls"] == 2:       # straggler on the 2nd batch
                time.sleep(0.002)
            return [p * 10 for p in payloads]

        b = Batcher(run, max_batch=2, hedge_factor=0.0)  # always hedge
        b.submit(1)
        b.drain()                         # establish EWMA (no hedge yet)
        r = b.submit(2)
        b.drain()
        assert r.done and r.hedged and r.result == 20
        # 2 requests total, each counted exactly once
        assert b.stats["requests"] == 2
        assert b.stats["batches"] == 2

    def test_ewma_learns_winner_not_straggler(self):
        import time
        state = {"calls": 0}
        SLOW, FAST = 0.02, 0.0

        def run(payloads):
            state["calls"] += 1
            time.sleep(SLOW if state["calls"] == 2 else FAST)
            return list(payloads)

        b = Batcher(run, max_batch=1, hedge_factor=0.0)  # always hedge
        b.submit("a")
        b.drain()
        ewma_before = b._lat_ewma
        b.submit("b")                     # straggles; hedge wins
        b.drain()
        # EWMA moved toward the hedge's fast service time, not the
        # straggler's SLOW time (0.2 * SLOW would exceed this bound)
        assert b._lat_ewma < ewma_before + 0.2 * SLOW / 2
