"""Serving substrate + data pipeline tests."""
import jax.numpy as jnp
import numpy as np

from repro.data.corpus import generate_corpus
from repro.data.pipeline import (Prefetcher, synthetic_lm_batches,
                                 synthetic_recsys_batches)
from repro.data.sampler import make_csr, sample_subgraph
from repro.serve.batcher import Batcher
from repro.serve.kv_cache import (CacheConfig, KVCacheArena, dequantize_kv,
                                  quantize_kv)


class TestCorpus:
    def test_shapes_and_determinism(self):
        c1 = generate_corpus(n_docs=10, n_versions=3, seed=4)
        c2 = generate_corpus(n_docs=10, n_versions=3, seed=4)
        assert c1.versions[0] == c2.versions[0]
        assert len(c1.versions) == 3 and len(c1.versions[0]) == 10
        assert len(c1.timestamps) == 3

    def test_edit_rate_in_paper_band(self):
        """Reprocessing fraction ~10-15% (paper Table II)."""
        from repro.core.cdc import detect_changes
        from repro.core.chunking import chunk_document
        c = generate_corpus(n_docs=20, n_versions=4, seed=0)
        fracs = []
        for v in range(1, 4):
            for d in c.doc_ids():
                new = chunk_document(c.versions[v][d])
                old = [ch.chunk_id for ch in
                       chunk_document(c.versions[v - 1][d])]
                cs = detect_changes(new, old)
                fracs.append(cs.reprocess_fraction)
        mean = float(np.mean(fracs))
        assert 0.08 <= mean <= 0.20, mean

    def test_edit_log_matches_cdc(self):
        """The generator's ground-truth log agrees with CDC detection."""
        from repro.core.cdc import detect_changes
        from repro.core.chunking import chunk_document
        c = generate_corpus(n_docs=8, n_versions=3, seed=1)
        for v in range(1, 3):
            logs = {l.doc_id: l for l in c.edit_logs[v]}
            for d in c.doc_ids():
                new = chunk_document(c.versions[v][d])
                old = [ch.chunk_id for ch in
                       chunk_document(c.versions[v - 1][d])]
                cs = detect_changes(new, old)
                detected_mod = {ch.position for ch in cs.modified}
                expected_mod = set(logs[d].modified)
                assert detected_mod == expected_mod, (d, v)

    def test_fact_values_change(self):
        c = generate_corpus(n_docs=5, n_versions=4, seed=2)
        f = c.facts[0]
        vals = [f.value_at_version(v) for v in range(4)]
        assert len(set(vals)) >= 2               # at least one change


class TestSampler:
    def test_fanout_subgraph(self):
        rng = np.random.default_rng(0)
        edges = rng.integers(0, 200, (2, 2000)).astype(np.int64)
        indptr, indices = make_csr(200, edges)
        seeds = np.arange(8)
        sg = sample_subgraph(indptr, indices, seeds, (5, 3), rng)
        assert sg.edge_index.shape == (2, 8 * 5 + 8 * 5 * 3)
        assert sg.node_ids.shape == (8 + 40 + 120,)
        assert sg.seed_mask[:8].all() and not sg.seed_mask[8:].any()
        # every real edge points from a later layer toward its parent
        valid = sg.edge_dist < 10.0
        assert (sg.edge_index[0][valid] > sg.edge_index[1][valid]).all() \
            or valid.sum() == 0

    def test_padded_edges_beyond_cutoff(self):
        rng = np.random.default_rng(0)
        edges = np.zeros((2, 2), np.int64)       # nearly edgeless graph
        indptr, indices = make_csr(10, edges)
        sg = sample_subgraph(indptr, indices, np.arange(4), (3,), rng,
                             cutoff=10.0)
        pad = sg.edge_dist >= 10.0
        assert pad.sum() >= sg.edge_dist.shape[0] - 2


class TestKVCache:
    def _cfg(self, quant=False):
        return CacheConfig(n_layers=2, n_kv=2, d_head=8, max_seq=16,
                           max_batch=4, quantize_int8=quant)

    def test_slot_lifecycle(self):
        arena = KVCacheArena(self._cfg())
        slots = [arena.claim() for _ in range(4)]
        assert arena.claim() is None             # full
        arena.release(slots[1])
        assert arena.claim() == slots[1]

    def test_prefill_roundtrip(self):
        arena = KVCacheArena(self._cfg())
        slot = arena.claim()
        k = jnp.ones((2, 2, 5, 8)) * 0.5
        arena.write_prefill(slot, k, k * 2)
        kk, vv = arena.dequantized([slot])
        np.testing.assert_allclose(np.asarray(kk[:, 0, :, :5]), 0.5)
        assert arena.lengths[slot] == 5

    def test_int8_quantization_error(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 2, 8, 8)).astype(np.float32))
        q, s = quantize_kv(x)
        x2 = dequantize_kv(q, s, jnp.float32)
        err = np.abs(np.asarray(x2 - x)).max()
        assert err < np.abs(np.asarray(x)).max() / 100

    def test_int8_memory_halves(self):
        # realistic head dim: per-vector f32 scale amortizes to 1/32
        cfg16 = CacheConfig(n_layers=2, n_kv=2, d_head=128, max_seq=16,
                            max_batch=4, quantize_int8=False)
        cfg8 = CacheConfig(n_layers=2, n_kv=2, d_head=128, max_seq=16,
                           max_batch=4, quantize_int8=True)
        a16, a8 = KVCacheArena(cfg16), KVCacheArena(cfg8)
        assert a8.memory_bytes() < 0.55 * a16.memory_bytes()


class TestBatcher:
    def test_batching_and_buckets(self):
        calls = []

        def run(payloads):
            calls.append(len(payloads))
            return [p * 2 for p in payloads]

        b = Batcher(run, max_batch=4, bucket_fn=lambda p: p % 2)
        reqs = [b.submit(i) for i in range(10)]
        b.drain()
        assert all(r.done for r in reqs)
        assert all(r.result == r.payload * 2 for r in reqs)
        assert max(calls) <= 4

    def test_hedging_triggers_on_straggler(self):
        import time as _t
        state = {"n": 0}

        def run(payloads):
            state["n"] += 1
            if state["n"] == 5:
                _t.sleep(0.2)                    # simulated straggler
            else:
                _t.sleep(0.01)
            return payloads

        b = Batcher(run, max_batch=1, hedge_factor=3.0)
        for i in range(8):
            b.submit(i)
        b.drain()
        assert b.stats["hedges"] >= 1


class TestPipeline:
    def test_prefetcher(self):
        def gen():
            for i in range(5):
                yield i

        assert list(Prefetcher(gen())) == [0, 1, 2, 3, 4]

    def test_synthetic_streams(self):
        b = next(synthetic_lm_batches(100, 4, 8))
        assert b["tokens"].shape == (4, 8)
        assert b["tokens"].min() >= 4 and b["tokens"].max() < 100
        r = next(synthetic_recsys_batches(5, 50, 8))
        assert r["ids"].shape == (8, 5)
        assert (r["ids"] < 250).all()
