"""Shard fabric tests (DESIGN.md §10): ring/manifest units, the
oracle-equivalence property over shard counts S in {1, 2, 4, 8},
replication + shard-failure tolerance, the device fan-out hook, and
crash-injected online rebalancing (split / merge / replica migration)
proving a killed migration never loses or double-serves a doc.

Equivalence definition (the planner's guarantee, stated executably by
``repro.shard.results_equivalent``): sharded results match the
single-lake oracle record for record and rank for rank wherever score
gaps exceed float noise; scores agree within (1e-5 rel, 1e-7 abs) —
BLAS/XLA round differently for different matrix shapes, so cross-layout
score BITS can differ by a few ulp; iso-score bands are unordered
(their order is layout-dependent on both sides).
"""
import tempfile

import numpy as np
import pytest

from repro.core.store import FaultInjected, LiveVectorLake
from repro.shard import (CorruptFabricManifest, FabricManifest, HashRing,
                         MigrationInterrupted, Rebalancer, ShardFabric,
                         ShardGatherError, device_fanout_topk,
                         results_equivalent)

DIM = 64
CAP = 8192      # exact-scan hot tier on every lake: both sides exhaustive


# ---------------------------------------------------------------------------
# corpus + equivalence helpers
# ---------------------------------------------------------------------------
VOCAB = ["alpha", "bravo", "carbon", "delta", "ember", "fjord", "glacier",
         "harbor", "isotope", "jetty", "kernel", "lagoon", "meadow",
         "nebula", "orchid", "plasma", "quartz", "rivet", "summit",
         "timber", "umbra", "vertex", "willow", "xylem", "yonder", "zephyr"]


def make_stream(rng, n_docs=12, n_versions=3, chunks=3, words=6):
    """Deterministic ingest stream [(doc_id, text, ts)] with strictly
    increasing ts, updates re-rolling a random chunk each version."""
    stream = []
    ts = 0
    texts = {}
    for v in range(n_versions):
        for i in range(n_docs):
            doc = f"doc{i}"
            if doc not in texts:
                texts[doc] = [" ".join(rng.choice(VOCAB, words))
                              for _ in range(chunks)]
            else:
                texts[doc][int(rng.integers(chunks))] = \
                    " ".join(rng.choice(VOCAB, words))
            ts += 1_000_000
            stream.append((doc, "\n\n".join(texts[doc]), ts))
    return stream


def drive(target, stream):
    for doc, text, ts in stream:
        target.ingest(doc, text, ts=ts)


def make_queries(rng, n=8, words=4):
    return [" ".join(rng.choice(VOCAB, words)) for _ in range(n)]


def assert_equivalent(oracle_res, fab_res, oracle_ext):
    assert results_equivalent(oracle_res, fab_res, oracle_ext), (
        [(r.doc_id, r.position, r.valid_from, r.score)
         for r in oracle_res],
        [(r.doc_id, r.position, r.valid_from, r.score)
         for r in fab_res])


def check_parity(oracle, fab, queries, k=5, **kw):
    o = oracle.query_batch(queries, k=k, **kw)
    oe = oracle.query_batch(queries, k=4 * k, **kw)
    f = fab.query_batch(queries, k=k, **kw)
    for qi in range(len(queries)):
        assert_equivalent(o[qi], f[qi], oe[qi])


# ---------------------------------------------------------------------------
# ring
# ---------------------------------------------------------------------------
class TestHashRing:
    def test_determinism_and_distinct_owners(self):
        ring = HashRing(["s0", "s1", "s2", "s3"], vnodes=32, replicas=3)
        for i in range(50):
            o1 = ring.owners(f"doc{i}")
            o2 = HashRing(["s3", "s1", "s0", "s2"], vnodes=32,
                          replicas=3).owners(f"doc{i}")
            assert o1 == o2                       # order-independent build
            assert len(set(o1)) == 3

    def test_replicas_clamped_and_validated(self):
        assert HashRing(["a", "b"], replicas=5).replicas == 2
        with pytest.raises(ValueError):
            HashRing([], replicas=1)
        with pytest.raises(ValueError):
            HashRing(["a", "a"])
        with pytest.raises(ValueError):
            HashRing(["a"], replicas=0)

    def test_minimal_movement_on_add(self):
        ring = HashRing([f"s{i}" for i in range(4)], vnodes=64)
        docs = [f"doc{i}" for i in range(400)]
        diff = ring.diff_owners(ring.with_shard("s4"), docs)
        # every changed doc gained the new shard, and only ~1/S move
        for d, (old, new) in diff.items():
            assert "s4" in new
        assert 0 < len(diff) < len(docs) // 2

    def test_remove_reverses_add(self):
        ring = HashRing(["s0", "s1", "s2"], vnodes=16, replicas=2)
        assert ring.with_shard("s3").without_shard("s3") == ring

    def test_roundtrip(self):
        ring = HashRing(["a", "b", "c"], vnodes=8, replicas=2)
        assert HashRing.from_dict(ring.to_dict()) == ring


# ---------------------------------------------------------------------------
# fabric manifest
# ---------------------------------------------------------------------------
class TestFabricManifest:
    def test_epochs_monotonic_and_atomic(self):
        with tempfile.TemporaryDirectory() as root:
            m = FabricManifest(root)
            assert m.load() is None
            assert m.commit({"ring": {"shards": ["a"]}}) == 1
            assert m.commit({"ring": {"shards": ["a", "b"]}}) == 2
            state = m.load()
            assert state["epoch"] == 2
            assert state["ring"]["shards"] == ["a", "b"]

    def test_checksum_detects_corruption(self):
        import os
        with tempfile.TemporaryDirectory() as root:
            m = FabricManifest(root)
            m.commit({"ring": {"shards": ["a"]}})
            path = os.path.join(root, "FABRIC.json")
            data = open(path).read()
            assert '"a"' in data
            open(path, "w").write(data.replace('"a"', '"b"'))
            assert m.load() is None               # checksum mismatch
            with pytest.raises(CorruptFabricManifest):
                ShardFabric(root, dim=DIM)


# ---------------------------------------------------------------------------
# oracle equivalence (the property of acceptance criterion 3)
# ---------------------------------------------------------------------------
class TestOracleEquivalence:
    @pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
    def test_sharded_matches_single_lake(self, n_shards):
        rng = np.random.default_rng(100 + n_shards)
        stream = make_stream(rng)
        queries = make_queries(rng)
        last_ts = stream[-1][2]
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            fab = ShardFabric(r2, n_shards=n_shards, dim=DIM,
                              hot_capacity=CAP)
            drive(oracle, stream)
            drive(fab, stream)
            check_parity(oracle, fab, queries)                  # current
            for ts in (stream[3][2], last_ts // 2, last_ts):    # temporal
                check_parity(oracle, fab, queries, at=ts)
            check_parity(oracle, fab, queries,                  # windows
                         window=(stream[2][2], last_ts // 2))
            check_parity(oracle, fab, queries, window=(1, last_ts + 1))

    def test_replicated_fabric_matches_oracle(self):
        rng = np.random.default_rng(7)
        stream = make_stream(rng, n_docs=10)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            fab = ShardFabric(r2, n_shards=4, replicas=2, dim=DIM,
                              hot_capacity=CAP)
            drive(oracle, stream)
            drive(fab, stream)
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=stream[-1][2] // 2)
            # every doc is on exactly R owner lakes
            for doc in fab.all_docs():
                holders = [s for s in fab.ring.shards
                           if fab.lake(s).has_doc(doc)]
                assert sorted(holders) == sorted(fab.ring.owners(doc))

    def test_reopened_fabric_clock_matches_oracle(self):
        """A fresh fabric process starts with _last_ts=0; its monotonic
        clock must sync from EVERY shard before the first resolution,
        or a stale explicit ts would resolve below instants other
        shards already stored (diverging from the oracle)."""
        rng = np.random.default_rng(77)
        stream = make_stream(rng, n_docs=12)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            fab = ShardFabric(r2, n_shards=4, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            drive(fab, stream)
            del fab
            fab2 = ShardFabric(r2)          # bare reopen, cold clock
            s_o = oracle.ingest("doc0", "quartz rivet summit",
                                ts=2_000_000)
            s_f = fab2.ingest("doc0", "quartz rivet summit",
                              ts=2_000_000)
            assert s_o.ts == s_f.ts
            check_parity(oracle, fab2, make_queries(rng))
            check_parity(oracle, fab2, make_queries(rng), at=s_f.ts - 1)

    def test_mixed_intent_batch_and_batcher(self):
        rng = np.random.default_rng(11)
        stream = make_stream(rng, n_docs=8)
        mid = stream[-1][2] // 2
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            drive(fab, stream)
            payloads = [("alpha bravo", None, None),
                        ("carbon delta", mid, None),
                        ("ember fjord", None, (1, mid)),
                        ("glacier harbor", None, None),
                        ("isotope jetty", mid, None)]
            b = fab.query_batcher(k=4)
            reqs = [b.submit(p) for p in payloads]
            b.drain()
            for req, (text, at, window) in zip(reqs, payloads):
                assert req.done and req.error is None
                o = oracle.query_batch([text], k=4, at=at, window=window)[0]
                oe = oracle.query_batch([text], k=16, at=at,
                                        window=window)[0]
                assert_equivalent(o, req.result, oe)


# ---------------------------------------------------------------------------
# failure tolerance
# ---------------------------------------------------------------------------
class TestShardFailure:
    def _fabric(self, root, rng, replicas):
        stream = make_stream(rng, n_docs=10)
        fab = ShardFabric(root, n_shards=4, replicas=replicas, dim=DIM,
                          hot_capacity=CAP)
        drive(fab, stream)
        return fab, stream

    def test_r1_shard_failure_fails_the_batch(self):
        rng = np.random.default_rng(21)
        with tempfile.TemporaryDirectory() as root:
            fab, _ = self._fabric(root, rng, replicas=1)
            dead = fab.ring.shards[1]

            def boom(*a, **k):
                raise RuntimeError("shard down")
            fab.lake(dead).query_batch = boom
            with pytest.raises(ShardGatherError):
                fab.query_batch(["alpha bravo"], k=3)

    def test_r2_survives_one_dead_shard_identically(self):
        rng = np.random.default_rng(22)
        stream = make_stream(rng, n_docs=10)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=4, replicas=2, dim=DIM,
                              hot_capacity=CAP)
            drive(fab, stream)
            dead = fab.ring.shards[2]

            def boom(*a, **k):
                raise RuntimeError("shard down")
            fab.lake(dead).query_batch = boom
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=stream[-1][2] // 2)
            assert fab.planner.stats["shard_failures"] > 0


# ---------------------------------------------------------------------------
# online rebalancing + crash injection
# ---------------------------------------------------------------------------
def exactly_once_docs(fab, stream):
    """Each doc's position-0 current chunk must appear exactly once in a
    query that retrieves it."""
    current = {}
    for doc, text, _ in stream:
        current[doc] = text.split("\n\n")[0]
    for doc, chunk in current.items():
        res = fab.query(chunk, k=10)
        hits = [r for r in res if r.doc_id == doc and r.position == 0]
        assert len(hits) == 1, (doc, len(hits))


class TestRebalance:
    def test_split_merge_replicas_keep_oracle_parity(self):
        rng = np.random.default_rng(31)
        stream = make_stream(rng, n_docs=12)
        queries = make_queries(rng)
        mid = stream[-1][2] // 2
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(fab, stream)
            rb = Rebalancer(fab)
            rep = rb.split("s03")
            assert rep["docs_copied"] > 0
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=mid)     # history moved
            rb.merge("s01")
            assert "s01" not in fab.ring.shards
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=mid)
            Rebalancer(fab).set_replicas(2)
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=mid)

    def test_ingest_during_copy_phase_lands_post_flip(self):
        """Docs created/updated while a migration is mid-copy must be
        served after the flip (union routing + dual-write)."""
        rng = np.random.default_rng(32)
        stream = make_stream(rng, n_docs=10, n_versions=2)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            drive(fab, stream)
            ts = stream[-1][2]
            with pytest.raises(MigrationInterrupted):
                Rebalancer(fab, fail_at="before_flip").split("s03")
            mid_stream = [("docnew", "quartz rivet summit\n\ntimber umbra",
                           ts + 1_000_000)]
            moving = sorted(fab._transition["docs"])
            for doc in moving[:1]:       # update an already-copied doc
                mid_stream.append((doc, "vertex willow xylem\n\nyonder "
                                   "zephyr alpha", ts + 2_000_000))
            drive(oracle, mid_stream)
            drive(fab, mid_stream)
            Rebalancer(fab).resume()
            assert fab.manifest.load()["transition"] is None
            queries = make_queries(rng) + ["quartz rivet summit",
                                           "vertex willow xylem"]
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=ts + 1_500_000)

    @pytest.mark.parametrize("fault", ["copy:0", "copy:1", "before_flip",
                                       "after_flip", "before_final"])
    def test_killed_split_recovers_exactly_once(self, fault):
        rng = np.random.default_rng(33)
        stream = make_stream(rng, n_docs=10)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(fab, stream)
            with pytest.raises(MigrationInterrupted):
                Rebalancer(fab, fail_at=fault).split("s03")
            # crashed mid-migration: a FRESH fabric (new process) resumes
            # from the manifest transition record on open
            fab2 = ShardFabric(r2, dim=DIM, hot_capacity=CAP)
            assert fab2.manifest.load()["transition"] is None
            assert "s03" in fab2.ring.shards
            exactly_once_docs(fab2, stream)
            check_parity(oracle, fab2, queries)
            check_parity(oracle, fab2, queries, at=stream[-1][2] // 2)

    def test_killed_import_mid_doc_recovers(self):
        """Crash INSIDE a doc's history import (partial cold commits on
        the destination): the event-idempotent import resumes without
        duplicating or losing rows."""
        rng = np.random.default_rng(34)
        stream = make_stream(rng, n_docs=10)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(fab, stream)
            with pytest.raises(FaultInjected):
                Rebalancer(fab, fail_import_after=1).split("s03")
            # bare reopen: dim/hot_capacity adopted from the manifest
            fab2 = ShardFabric(r2)
            assert fab2.manifest.load()["transition"] is None
            exactly_once_docs(fab2, stream)
            check_parity(oracle, fab2, queries)
            check_parity(oracle, fab2, queries, at=stream[-1][2] // 2)

    def test_killed_merge_recovers(self):
        rng = np.random.default_rng(35)
        stream = make_stream(rng, n_docs=10)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=4, dim=DIM, hot_capacity=CAP)
            drive(fab, stream)
            victim = fab.ring.shards[0]
            with pytest.raises(MigrationInterrupted):
                Rebalancer(fab, fail_at="after_flip").merge(victim)
            fab2 = ShardFabric(r2, dim=DIM, hot_capacity=CAP)
            assert victim not in fab2.ring.shards
            exactly_once_docs(fab2, stream)
            check_parity(oracle, fab2, queries)
            check_parity(oracle, fab2, queries, at=stream[-1][2] // 2)

    def test_doc_can_move_back_to_former_owner(self):
        """split then merge moves some docs back to a shard that once
        served them (stale cold history on the destination): event-level
        idempotent import must reconcile, not duplicate."""
        rng = np.random.default_rng(36)
        stream = make_stream(rng, n_docs=12)
        queries = make_queries(rng)
        with tempfile.TemporaryDirectory() as r1, \
                tempfile.TemporaryDirectory() as r2:
            oracle = LiveVectorLake(r1, dim=DIM, hot_capacity=CAP)
            drive(oracle, stream)
            fab = ShardFabric(r2, n_shards=3, dim=DIM, hot_capacity=CAP)
            drive(fab, stream)
            rb = Rebalancer(fab)
            rb.split("s03")
            rb.merge("s03")             # everything moves home again
            exactly_once_docs(fab, stream)
            check_parity(oracle, fab, queries)
            check_parity(oracle, fab, queries, at=stream[-1][2] // 2)


# ---------------------------------------------------------------------------
# device fan-out hook
# ---------------------------------------------------------------------------
class TestDeviceFanout:
    def test_matches_per_shard_dispatch(self):
        from repro.kernels.topk_search.ops import topk_search
        rng = np.random.default_rng(40)
        S, N, d, Q, k = 4, 192, 32, 5, 7
        emb = rng.standard_normal((S, N, d)).astype(np.float32)
        mask = rng.random((S, N)) > 0.25
        q = rng.standard_normal((Q, d)).astype(np.float32)
        s, i = device_fanout_topk(q, emb, mask, k)
        assert s.shape == (S, Q, k) and i.shape == (S, Q, k)
        for si in range(S):
            rs, ri = topk_search(q, emb[si], mask[si], k)
            assert np.array_equal(np.asarray(rs), s[si])
            assert np.array_equal(np.asarray(ri), i[si])

    def test_shard_map_path_on_host_mesh(self):
        from repro.launch.mesh import make_host_mesh
        rng = np.random.default_rng(41)
        S, N, d, Q, k = 2, 128, 16, 3, 5
        emb = rng.standard_normal((S, N, d)).astype(np.float32)
        mask = np.ones((S, N), bool)
        q = rng.standard_normal((Q, d)).astype(np.float32)
        base = device_fanout_topk(q, emb, mask, k)
        fanned = device_fanout_topk(q, emb, mask, k,
                                    mesh=make_host_mesh(1, 1))
        assert np.array_equal(base[0], fanned[0])
        assert np.array_equal(base[1], fanned[1])
