"""SLO engine tests (src/repro/obs/slo.py — DESIGN.md §15): burn-rate
correctness against synthetic traffic with KNOWN violation rates on
both windows (driven through a fake clock so real window arithmetic is
exercised), the ok -> warning -> burning state machine, intent token
matching, degraded accounting, published gauges, and the trace-exit
integration."""
import itertools

import pytest

from repro import obs
from repro.obs import REGISTRY
from repro.obs.slo import SLOEngine, intent_matches

_uid = itertools.count()


def _tenant():
    """Unique tenant per test: the engine's histograms live in the
    process-wide registry, so reusing a name would leak one test's
    traffic into the next's cold-start window."""
    return f"t{next(_uid)}"


@pytest.fixture()
def clockeng():
    clock = [0.0]
    eng = SLOEngine(clock=lambda: clock[0], resolution_s=1.0)
    return clock, eng


@pytest.fixture(autouse=True)
def _clean():
    obs.set_enabled(True)
    obs.SLO_ENGINE.reset()
    yield
    obs.SLO_ENGINE.reset()


class TestIntentMatching:
    def test_wildcard_and_none_match_everything(self):
        assert intent_matches("*", "anything")
        assert intent_matches(None, "anything")
        assert intent_matches("*", None)

    def test_token_match_against_rendered_bucket(self):
        bucket = "(TemporalIntent(mode='at', at=5000), None)"
        assert intent_matches("at", bucket)
        assert not intent_matches("current", bucket)
        assert intent_matches(
            "current", "(TemporalIntent(mode='current'), None)")

    def test_at_does_not_substring_match_comparative(self):
        # 'at' IS a substring of 'comparative' — token matching is the
        # whole point of the helper
        assert not intent_matches("at", "comparative")
        assert intent_matches("comparative", "comparative")

    def test_no_intent_matches_only_wildcard(self):
        assert not intent_matches("current", None)


class TestBurnRates:
    def test_known_violation_rate_on_both_windows(self, clockeng):
        clock, eng = clockeng
        tenant = _tenant()
        # target 0.99 => 1% error budget; exactly 10% of requests land
        # way over the 50ms threshold => burn = 0.10 / 0.01 = 10
        eng.declare(tenant, "current", latency_ms=50.0, target=0.99,
                    windows_s=(60.0, 300.0))
        for i in range(800):           # 400s of traffic at 2 req/s
            clock[0] += 0.5
            eng.observe(tenant, "current",
                        500.0 if i % 10 == 9 else 5.0)
        r = eng.burn_rates(tenant, "current")
        for window in ("60s", "300s"):
            assert r["burn"][window] == pytest.approx(10.0, rel=0.15), \
                (window, r["burn"])

    def test_short_window_recovers_before_long(self, clockeng):
        clock, eng = clockeng
        tenant = _tenant()
        eng.declare(tenant, "current", latency_ms=50.0, target=0.99,
                    windows_s=(60.0, 300.0))
        for i in range(400):           # 200s at 50% violations: burning
            clock[0] += 0.5
            eng.observe(tenant, "current", 500.0 if i % 2 else 5.0)
        assert eng.burn_rates(tenant, "current")["state"] == "burning"
        for _ in range(160):           # 80s fully healthy
            clock[0] += 0.5
            eng.observe(tenant, "current", 5.0)
        r = eng.burn_rates(tenant, "current")
        # short window sees only healthy traffic; the long window still
        # contains the incident — exactly the multi-window alert rule
        assert r["burn"]["60s"] == pytest.approx(0.0, abs=0.5)
        assert r["burn"]["300s"] > 4.0
        assert r["state"] == "warning"      # long alone can't page

    def test_errors_count_against_availability(self, clockeng):
        clock, eng = clockeng
        tenant = _tenant()
        eng.declare(tenant, "*", latency_ms=1e6, target=0.999)
        for i in range(100):
            clock[0] += 1.0
            eng.observe(tenant, "current", 1.0,
                        ok=(i % 20 != 19))       # 5% hard failures
        r = eng.burn_rates(tenant, "*")
        assert r["burn"]["60s"] == pytest.approx(0.05 / 0.001, rel=0.2)
        assert r["errors"] == 5

    def test_no_traffic_is_zero_burn_ok(self, clockeng):
        _, eng = clockeng
        tenant = _tenant()
        eng.declare(tenant)
        r = eng.burn_rates(tenant)
        assert r["burn"] == {"60s": 0.0, "300s": 0.0}
        assert r["state"] == "ok"

    def test_degraded_bad_burns_budget(self, clockeng):
        clock, eng = clockeng
        t_strict, t_lax = _tenant(), _tenant()
        eng.declare(t_strict, "*", latency_ms=1e6, target=0.999,
                    degraded_bad=True)
        eng.declare(t_lax, "*", latency_ms=1e6, target=0.999,
                    degraded_bad=False)
        for tenant in (t_strict, t_lax):
            clock[0] += 1.0
            eng.observe(tenant, "current", 1.0, ok=True, degraded=True)
            eng.observe(tenant, "current", 1.0, ok=True)
        assert eng.burn_rates(t_strict)["burn"]["60s"] > 0.0
        assert eng.burn_rates(t_lax)["burn"]["60s"] == 0.0
        assert eng.burn_rates(t_lax)["degraded"] == 1


class TestStateMachine:
    def _feed(self, eng, clock, tenant, n, bad_every):
        for i in range(n):
            clock[0] += 0.5
            bad = bad_every and i % bad_every == bad_every - 1
            eng.observe(tenant, "current", 500.0 if bad else 5.0)

    def test_warning_needs_one_window_burning_needs_both(self, clockeng):
        clock, eng = clockeng
        tenant = _tenant()
        # budget 1%: warn at burn>=1 (1% bad), page at burn>=4 (4% bad)
        eng.declare(tenant, "*", latency_ms=50.0, target=0.99,
                    windows_s=(60.0, 300.0), warn_burn=1.0,
                    page_burn=4.0)
        self._feed(eng, clock, tenant, 700, bad_every=50)   # 2% bad
        r = eng.burn_rates(tenant)
        assert r["state"] == "warning", r["burn"]
        self._feed(eng, clock, tenant, 700, bad_every=10)   # 10% bad
        r = eng.burn_rates(tenant)
        assert r["state"] == "burning", r["burn"]
        assert r["transitions"] >= 2
        assert REGISTRY.counter("slo_state_changes", tenant=tenant,
                                intent="*").value >= 2

    def test_burn_gauges_published(self, clockeng):
        clock, eng = clockeng
        tenant = _tenant()
        eng.declare(tenant, "current", latency_ms=50.0, target=0.99)
        for i in range(100):
            clock[0] += 0.5
            eng.observe(tenant, "current", 500.0 if i % 2 else 5.0)
        r = eng.burn_rates(tenant, "current")
        for window in ("60s", "300s"):
            g = REGISTRY.gauge("slo_burn_rate", tenant=tenant,
                               intent="current", window=window)
            assert g.value == pytest.approx(r["burn"][window])

    def test_summary_reports_worst_state(self, clockeng):
        clock, eng = clockeng
        t_ok, t_burn = _tenant(), _tenant()
        eng.declare(t_ok, "*", latency_ms=1e6, target=0.99)
        eng.declare(t_burn, "*", latency_ms=50.0, target=0.99)
        for _ in range(200):
            clock[0] += 0.5
            eng.observe(t_ok, "current", 1.0)
            eng.observe(t_burn, "current", 500.0)     # 100% bad
        s = eng.summary()
        assert s["declared"] == 2
        assert s["worst_state"] == "burning"
        states = {x["tenant"]: x["state"] for x in s["slos"]}
        assert states[t_ok] == "ok"
        assert states[t_burn] == "burning"


class TestTraceIntegration:
    def test_finished_traces_feed_the_singleton(self):
        tenant = _tenant()
        obs.SLO_ENGINE.declare(tenant, "*", latency_ms=1e6,
                               target=0.999)
        with obs.trace("request", intent="current", tenant=tenant):
            pass
        with pytest.raises(ValueError):
            with obs.trace("request", intent="current", tenant=tenant):
                raise ValueError("boom")
        r = obs.SLO_ENGINE.burn_rates(tenant, "*")
        assert r["requests"] == 2
        assert r["errors"] == 1

    def test_engine_inactive_without_declarations(self):
        assert not obs.SLO_ENGINE.active
        # no declarations: traces must not create slo series
        with obs.trace("request", intent="current", tenant="ghost"):
            pass
        key = "slo_latency_ms{intent=*,tenant=ghost}"
        assert key not in REGISTRY.snapshot()["histograms"]

    def test_untenanted_traces_ignored(self):
        tenant = _tenant()
        obs.SLO_ENGINE.declare(tenant, "*", latency_ms=1e6)
        with obs.trace("request", intent="current"):
            pass
        assert obs.SLO_ENGINE.burn_rates(tenant)["requests"] == 0
