"""End-to-end LiveVectorLake behaviour: ingest -> dual-tier -> query,
WAL crash recovery, temporal leakage prevention (paper §III, §V)."""
import pytest

from repro.core.store import FaultInjected, LiveVectorLake
from repro.core.types import VALID_TO_OPEN

DIM = 64

V1 = """The quarterly revenue was 10 million dollars.

Security policy requires two factor authentication.

The incident response time target is four hours."""

V2 = """The quarterly revenue was 12 million dollars.

Security policy requires two factor authentication.

The incident response time target is four hours."""

V3 = """The quarterly revenue was 12 million dollars.

Security policy requires hardware security keys for all staff.

The incident response time target is two hours.

A new disaster recovery site was opened in Frankfurt."""


@pytest.fixture
def store(tmp_path):
    return LiveVectorLake(str(tmp_path / "lvl"), dim=DIM)


class TestIngestCDC:
    def test_initial_ingest(self, store):
        s = store.ingest("doc1", V1, ts=1_000_000)
        assert s.n_new == 3 and s.n_embedded == 3
        assert s.reprocess_fraction == 1.0
        assert len(store.hot) == 3

    def test_selective_reprocessing(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        s2 = store.ingest("doc1", V2, ts=2_000_000)
        assert s2.n_modified == 1 and s2.n_unchanged == 2
        assert s2.n_embedded == 1                      # only the changed chunk
        assert abs(s2.reprocess_fraction - 1 / 3) < 1e-9
        s3 = store.ingest("doc1", V3, ts=3_000_000)
        assert s3.n_modified == 2 and s3.n_new == 1 and s3.n_unchanged == 1

    def test_cross_document_dedup(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        s = store.ingest("doc2", V1, ts=2_000_000)     # same content, new doc
        assert s.n_new == 3
        assert s.n_embedded == 0 and s.n_dedup_hits == 3   # zero embed ops

    def test_hot_tier_only_active(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        store.ingest("doc1", V3, ts=3_000_000)
        assert len(store.hot) == 4                     # V3 has 4 chunks
        st = store.stats()
        assert st["cold"]["total_records"] == 3 + 1 + 3   # all versions kept
        assert st["hot_fraction_of_history"] < 1.0

    def test_document_truncation_deletes(self, store):
        store.ingest("doc1", V3, ts=1_000_000)
        store.ingest("doc1", V1, ts=2_000_000)         # 4 chunks -> 3
        assert len(store.hot) == 3


class TestQueries:
    def test_current_query_hot_tier(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        res = store.query("quarterly revenue dollars", k=2)
        assert res and res[0].tier == "hot"
        assert "revenue" in res[0].text

    def test_current_reflects_update(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        res = store.query("quarterly revenue", k=1)
        assert "12 million" in res[0].text

    def test_historical_query_returns_old_version(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        res = store.query("quarterly revenue", k=1, at=1_500_000)
        assert res[0].tier == "cold"
        assert "10 million" in res[0].text             # the historical truth

    def test_temporal_leakage_prevention(self, store):
        """Chunks created later must NEVER surface at an earlier ts."""
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V3, ts=2_000_000)
        res = store.query("disaster recovery Frankfurt", k=5, at=1_500_000)
        assert all("frankfurt" not in r.text.lower() for r in res)
        res_now = store.query("disaster recovery Frankfurt", k=5)
        assert any("Frankfurt" in r.text for r in res_now)

    def test_deleted_chunk_not_in_history_after(self, store):
        store.ingest("doc1", V3, ts=1_000_000)         # has Frankfurt para
        store.ingest("doc1", V1, ts=2_000_000)         # removed
        res = store.query("disaster recovery", k=5, at=2_500_000)
        assert all("frankfurt" not in r.text.lower() for r in res)
        res_old = store.query("disaster recovery", k=5, at=1_500_000)
        assert any("Frankfurt" in r.text for r in res_old)

    def test_comparative_window(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        res = store.query("quarterly revenue", k=5,
                          window=(500_000, 2_500_000))
        texts = {r.text for r in res if "revenue" in r.text}
        assert len(texts) == 2                         # both versions visible

    def test_text_temporal_parsing(self, store):
        from repro.core.temporal import classify_query
        i = classify_query("security policy as of 2025-03-01")
        assert i.mode == "historical" and i.at is not None
        i = classify_query("revenue between 2025-01-01 and 2025-06-01")
        assert i.mode == "comparative"
        assert classify_query("plain query").mode == "current"


class TestFaultTolerance:
    def test_crash_after_cold_rolls_forward(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        with pytest.raises(FaultInjected):
            store.ingest("doc1", V2, ts=2_000_000, fail_after="cold")
        # restart
        store2 = LiveVectorLake(root, dim=DIM)
        assert not store2.wal.pending()
        res = store2.query("quarterly revenue", k=1)
        assert "12 million" in res[0].text             # V2 is visible
        assert len(store2.hot) == 3

    def test_crash_after_intent_aborts(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        with pytest.raises(FaultInjected):
            store.ingest("doc1", V2, ts=2_000_000, fail_after="intent")
        store2 = LiveVectorLake(root, dim=DIM)
        assert not store2.wal.pending()
        res = store2.query("quarterly revenue", k=1)
        assert "10 million" in res[0].text             # V2 never happened

    def test_compensation_policy(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        with pytest.raises(FaultInjected):
            store.ingest("doc1", V2, ts=2_000_000, fail_after="cold")
        report = store.reconcile(policy="compensate")
        assert report["compensated"] == 1
        # the compensated commit is invisible to readers
        snap = store.cold.snapshot()
        texts = " ".join(snap.texts)
        assert "12 million" not in texts

    def test_compensation_evicts_resident_history(self, tmp_path):
        """Regression: a temporal query BETWEEN the crash and the
        compensation folds the (still-committed) entry into the
        engine's resident arrays; compensation must evict it — the
        fused path may never serve rolled-back rows or keep valid rows
        closed by a rolled-back closure."""
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        with pytest.raises(FaultInjected):
            store.ingest("doc1", V2, ts=2_000_000, fail_after="cold")
        # this query seeds the resident history WITH the doomed commit
        store.query("quarterly revenue", k=1, at=2_500_000)
        store.reconcile(policy="compensate")
        res = store.query("quarterly revenue", k=1, at=2_500_000)
        assert res and "10 million" in res[0].text     # V1 valid again
        assert "12 million" not in " ".join(r.text for r in res)

    def test_hot_tier_rebuild_deterministic(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V3, ts=2_000_000)
        store.ingest("doc2", V2, ts=3_000_000)
        before = sorted(store.hot._by_key)
        store2 = LiveVectorLake(root, dim=DIM)
        assert sorted(store2.hot._by_key) == before
        q = "incident response time"
        r1, r2 = store.query(q, k=3), store2.query(q, k=3)
        assert [x.chunk_id for x in r1] == [x.chunk_id for x in r2]

    def test_wal_torn_line_recovery(self, tmp_path):
        root = str(tmp_path / "lvl")
        store = LiveVectorLake(root, dim=DIM)
        store.ingest("doc1", V1, ts=1_000_000)
        with open(store.wal._path, "a") as f:
            f.write('{"txn": 99, "state": "INT')        # torn write
        store2 = LiveVectorLake(root, dim=DIM)           # must not crash
        assert len(store2.hot) == 3


class TestAuditTrail:
    def test_history_positions(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        hist = store.cold.history("doc1")
        pos0 = [h for h in hist if h["position"] == 0]
        assert len(pos0) == 2                          # original + superseded
        assert pos0[0]["status"] == "superseded"
        assert pos0[0]["valid_to"] == 2_000_000
        assert pos0[1]["status"] == "active"
        assert pos0[1]["valid_from"] == 2_000_000

    def test_validity_intervals_contiguous(self, store):
        store.ingest("doc1", V1, ts=1_000_000)
        store.ingest("doc1", V2, ts=2_000_000)
        store.ingest("doc1", V3, ts=3_000_000)
        hist = store.cold.history("doc1")
        for pos in range(3):
            recs = sorted((h for h in hist if h["position"] == pos),
                          key=lambda h: h["valid_from"])
            for a, b in zip(recs, recs[1:]):
                assert a["valid_to"] == b["valid_from"]   # no gaps, no overlap
            assert recs[-1]["valid_to"] == VALID_TO_OPEN
