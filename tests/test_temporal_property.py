"""Temporal-correctness battery (ISSUE 3).

Two layers:

  - seeded-random fuzz (always runs): temporal-leakage invariants on
    BOTH the fused kernel path and the NumPy oracle path, across random
    ts grids, batch queries, and instants exactly at valid_from /
    valid_to boundaries; plus snapshot-equivalence sweeps over random
    commit/supersede/delete interleavings.
  - hypothesis property tests (skip cleanly when hypothesis is absent,
    like tests/test_property.py): the same invariants driven by
    minimized adversarial op sequences.

The oracle everywhere is the from-scratch O(history) log fold
(``snapshot(from_scratch=True)``) — byte-identical snapshot equality,
including ``include_closed=True`` and exact ``valid_to`` metadata.
"""
import numpy as np
import pytest

from repro.core.cold_tier import ColdTier
from repro.core.store import LiveVectorLake
from repro.core.temporal import TemporalEngine
from repro.core.types import ChunkRecord, VALID_TO_OPEN

DIM = 16


def _rec(doc, pos, tag, ts):
    rng = np.random.default_rng(abs(hash((doc, pos, tag))) % 2**31)
    e = rng.standard_normal(DIM).astype(np.float32)
    e /= np.linalg.norm(e)
    return ChunkRecord(chunk_id=f"h-{doc}-{pos}-{tag}", doc_id=doc,
                       position=pos, valid_from=ts, text=f"{doc}@{pos}:{tag}",
                       embedding=e)


def apply_ops(ct: ColdTier, ops, t0=1000, dt=100, compact_at=None):
    """Apply a commit/supersede/delete op sequence the way the store
    does: every write to an occupied (doc, pos) slot closes it first,
    deletes close without writing. Returns (commit timestamps, end ts).

    ops: list of commits; each commit is a list of (doc, pos, action)
    with action in {"write", "delete"}.
    """
    open_slots: set = set()
    ts = t0
    stamps = []
    for ci, commit in enumerate(ops):
        records, closures, seen = [], [], set()
        for doc, pos, action in commit:
            key = (doc, pos)
            if key in seen:
                continue                      # one op per slot per commit
            seen.add(key)
            if key in open_slots:
                closures.append({"doc_id": doc, "position": pos,
                                 "closed_at": ts,
                                 "status": ("superseded" if action == "write"
                                            else "deleted")})
                if action == "delete":
                    open_slots.discard(key)
            elif action == "delete":
                continue                      # nothing to delete
            if action == "write":
                records.append(_rec(doc, pos, f"c{ci}", ts))
                open_slots.add(key)
        ct.commit(records, closures, ts)
        stamps.append(ts)
        if compact_at is not None and ci == compact_at:
            ct.compact()
        ts += dt
    return stamps, ts


def assert_snapshots_identical(ct: ColdTier, ts_grid, tag=""):
    for ts in ts_grid:
        for inc in (False, True):
            a = ct.snapshot(as_of_ts=int(ts), include_closed=inc)
            b = ct.snapshot(as_of_ts=int(ts), include_closed=inc,
                            from_scratch=True)
            ctx = f"{tag} ts={ts} include_closed={inc}"
            assert a.chunk_ids == b.chunk_ids, ctx
            np.testing.assert_array_equal(a.valid_from, b.valid_from,
                                          err_msg=ctx)
            np.testing.assert_array_equal(a.valid_to, b.valid_to,
                                          err_msg=ctx)
            np.testing.assert_array_equal(a.embeddings, b.embeddings,
                                          err_msg=ctx)
            assert a.texts == b.texts, ctx
            assert a.as_of == b.as_of, ctx


def _random_ops(rng, n_commits, n_docs=3, n_pos=3):
    ops = []
    for _ in range(n_commits):
        n = int(rng.integers(1, 4))
        commit = []
        for _ in range(n):
            commit.append((f"d{rng.integers(0, n_docs)}",
                           int(rng.integers(0, n_pos)),
                           "delete" if rng.random() < 0.25 else "write"))
        ops.append(commit)
    return ops


class TestSnapshotEquivalenceSeeded:
    """Checkpointed/archived snapshot == from-scratch fold, on random
    interleavings (always runs; the hypothesis class below drives the
    same property with minimized counterexamples)."""

    @pytest.mark.parametrize("seed", range(8))
    def test_random_interleavings(self, tmp_path, seed):
        rng = np.random.default_rng(seed)
        interval = int(rng.choice([1, 2, 3, 5]))
        ct = ColdTier(str(tmp_path), dim=DIM, checkpoint_interval=interval)
        ops = _random_ops(rng, n_commits=int(rng.integers(5, 18)))
        compact_at = (int(rng.integers(0, len(ops)))
                      if rng.random() < 0.5 else None)
        stamps, end = apply_ops(ct, ops, compact_at=compact_at)
        # grid: random instants + every commit instant and its neighbors
        grid = set(int(x) for x in rng.integers(900, end + 200, 12))
        for s in stamps:
            grid.update((s - 1, s, s + 1))
        assert_snapshots_identical(ct, sorted(grid), tag=f"seed={seed}")

    def test_compact_then_more_commits(self, tmp_path):
        """Archives stay exact when new commits (and closures targeting
        re-opened slots) land AFTER compaction."""
        ct = ColdTier(str(tmp_path), dim=DIM, checkpoint_interval=0)
        ops1 = [[("d0", 0, "write")], [("d0", 0, "write")],
                [("d0", 0, "write")], [("d1", 0, "write")],
                [("d0", 0, "delete"), ("d1", 0, "write")]]
        stamps1, end1 = apply_ops(ct, ops1)
        ct.compact()
        ops2 = [[("d0", 0, "write")], [("d0", 0, "write")],
                [("d1", 0, "delete")]]
        stamps2, end2 = apply_ops(ct, ops2, t0=end1)
        grid = [s + d for s in stamps1 + stamps2 for d in (-1, 0, 1)]
        assert_snapshots_identical(ct, grid + [end2 + 10**6])


class TestLeakageFuzzSeeded:
    """assert_no_leakage fuzzed across random ts grids and batch queries
    on BOTH the fused kernel path and the NumPy oracle path, including
    instants exactly at valid_from/valid_to boundaries."""

    @pytest.fixture(scope="class")
    def store(self, tmp_path_factory):
        root = tmp_path_factory.mktemp("leak")
        store = LiveVectorLake(str(root), dim=32,
                               cold_checkpoint_interval=3)
        texts = ["alpha beta gamma.\n\ndelta epsilon zeta.",
                 "alpha beta UPDATED.\n\ndelta epsilon zeta.",
                 "alpha beta UPDATED.\n\nnew paragraph entirely.",
                 "final alpha content."]
        self_ts = []
        for v, t in enumerate(texts):
            s = store.ingest("doc-a", t, ts=1_000_000 + v * 1_000)
            self_ts.append(s.ts)
        for v, t in enumerate(texts[::-1]):
            store.ingest("doc-b", t, ts=1_010_000 + v * 1_000)
        return store

    def _boundary_instants(self, store):
        snap = store.cold.snapshot(include_closed=True)
        out = set()
        for i in range(len(snap)):
            vf, vt = int(snap.valid_from[i]), int(snap.valid_to[i])
            out.update((vf - 1, vf, vf + 1))
            if vt != VALID_TO_OPEN:
                out.update((vt - 1, vt, vt + 1))
        return sorted(out)

    def _engines(self, store):
        oracle = TemporalEngine(store.cold, fused=False)
        return [("fused", store.temporal), ("oracle", oracle)]

    def test_point_queries_no_leakage(self, store):
        rng = np.random.default_rng(0)
        instants = self._boundary_instants(store)
        instants += [int(x) for x in
                     rng.integers(990_000, 1_030_000, 20)]
        q = rng.standard_normal((4, 32)).astype(np.float32)
        for name, eng in self._engines(store):
            for ts in instants:
                res = eng.query_at_batch(q, ts, k=6)
                for row in res:
                    eng.assert_no_leakage(row, ts)   # raises on leakage

    def test_batch_equals_sequential_on_boundaries(self, store):
        """A query returns the same records at the same ranks alone or
        inside a batch (scores equal to ULP-level BLAS tolerance), on
        both paths, at exact validity boundaries."""
        rng = np.random.default_rng(1)
        q = rng.standard_normal((5, 32)).astype(np.float32)
        for name, eng in self._engines(store):
            for ts in self._boundary_instants(store)[:12]:
                batch = eng.query_at_batch(q, ts, k=4)
                for i in range(q.shape[0]):
                    single = eng.query_at(q[i], ts, k=4)
                    assert [r.chunk_id for r in batch[i]] == \
                        [r.chunk_id for r in single], (name, ts, i)
                    for x, y in zip(batch[i], single):
                        assert abs(x.score - y.score) < 1e-5, (name, ts, i)

    def test_fused_and_oracle_same_records(self, store):
        """Same chunk sets at every fuzzed instant (scores may differ at
        ULP level between the two matmul shapes)."""
        rng = np.random.default_rng(2)
        q = rng.standard_normal((3, 32)).astype(np.float32)
        engines = dict(self._engines(store))
        for ts in self._boundary_instants(store):
            rf = engines["fused"].query_at_batch(q, ts, k=8)
            ro = engines["oracle"].query_at_batch(q, ts, k=8)
            for a, b in zip(rf, ro):
                assert {r.chunk_id for r in a} == {r.chunk_id for r in b}, ts
                for x, y in zip(a, b):
                    assert abs(x.score - y.score) < 1e-4

    def test_window_queries_no_leakage(self, store):
        rng = np.random.default_rng(3)
        instants = self._boundary_instants(store)
        for name, eng in self._engines(store):
            for _ in range(15):
                t0, t1 = sorted(rng.choice(instants, 2, replace=False))
                if t0 == t1:
                    t1 += 1
                res = eng.query_window_batch(
                    rng.standard_normal((3, 32)).astype(np.float32),
                    int(t0), int(t1), k=5)
                for row in res:
                    eng.assert_no_window_leakage(row, int(t0), int(t1))


# ----------------------------------------------------------------------
# hypothesis layer (optional dependency, like tests/test_property.py)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _op = st.tuples(st.sampled_from(["d0", "d1", "d2"]),
                    st.integers(0, 2),
                    st.sampled_from(["write", "write", "delete"]))
    _commit = st.lists(_op, min_size=1, max_size=3)
    _ops = st.lists(_commit, min_size=1, max_size=12)

    class TestSnapshotEquivalenceHypothesis:
        @given(ops=_ops, interval=st.sampled_from([1, 2, 3, 5]),
               do_compact=st.booleans())
        @settings(max_examples=40, deadline=None)
        def test_checkpointed_fold_identical(self, tmp_path_factory, ops,
                                             interval, do_compact):
            """Under ANY interleaved commit/supersede/delete sequence,
            the checkpointed (and optionally compacted) snapshot is
            record-for-record identical to the from-scratch log fold for
            every ts on the sampled grid, include_closed included."""
            root = tmp_path_factory.mktemp("hyp")
            ct = ColdTier(str(root), dim=DIM,
                          checkpoint_interval=interval)
            stamps, end = apply_ops(
                ct, ops, compact_at=(len(ops) - 1 if do_compact else None))
            grid = sorted({t + d for t in stamps for d in (-1, 0, 1)}
                          | {900, end + 10**6})
            assert_snapshots_identical(ct, grid, tag="hypothesis")

        @given(ops=_ops, k=st.integers(1, 6))
        @settings(max_examples=25, deadline=None)
        def test_fused_path_no_leakage(self, tmp_path_factory, ops, k):
            """The fused kernel path never returns a chunk whose validity
            interval misses the query instant, at any commit boundary."""
            root = tmp_path_factory.mktemp("hypleak")
            ct = ColdTier(str(root), dim=DIM, checkpoint_interval=2)
            stamps, end = apply_ops(ct, ops)
            eng = TemporalEngine(ct, fused=True)
            rng = np.random.default_rng(0)
            q = rng.standard_normal((2, DIM)).astype(np.float32)
            for ts in {t + d for t in stamps for d in (-1, 0, 1)}:
                for row in eng.query_at_batch(q, int(ts), k=k):
                    eng.assert_no_leakage(row, int(ts))
