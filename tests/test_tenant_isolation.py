"""Cross-tenant isolation battery (DESIGN.md §14).

Layers, mirroring tests/test_temporal_property.py:

  - merge-audit regression tests: ``merge_topk_candidates`` padding
    rows (gid -1) must never alias global row 0 through the
    ``np.clip`` authority gather — with 1-D authority AND with the
    planner's 2-D per-candidate mask, even when a caller hands an
    all-True column for the padding slots.
  - registry unit tests: persistence, fail-closed unknown names,
    ``visible_rows`` mask semantics.
  - seeded-random fuzz: multi-tenant ingest interleavings, then
    current / point-in-time / window queries under every single- and
    multi-tenant visibility scope on the fused hot path, IVF segments,
    the fused temporal kernel AND the NumPy oracle, at fp32 and int8
    (solo segments appear on the quantized reopen, where config drift
    demotes data-scaled segments out of the fused block) — asserting
    ZERO foreign-tenant rows everywhere, including after a full
    reopen-from-disk recovery.
  - equivalence: an all-tenants scope and a single-tenant scope over a
    single-tenant corpus are byte-identical to the unscoped query.
  - serving gates: per-tenant queue quota + token-bucket rate limit in
    the batcher, visibility-scoped batch bucketing, tenant-labeled
    trace attributes, and the bounded counted ingest admission path.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.store import LiveVectorLake
from repro.core.temporal import TemporalEngine
from repro.core.tenancy import TenantRegistry, visible_rows, visibility_key
from repro.index.lsm import merge_topk_candidates
from repro.serve.batcher import AdmissionRejected, Batcher, intent_batcher

DIM = 32
TENANTS = ["", "acme", "globex", "initech"]


# ----------------------------------------------------------------------
# merge_topk_candidates padding audit (ISSUE satellite: the np.clip
# gather aliases gid -1 onto row 0; (gids >= 0) must be applied FIRST)
# ----------------------------------------------------------------------
class TestMergePaddingAliasing:
    def test_padding_never_aliases_row0_authority(self):
        """A padding candidate (gid -1) with a huge score must lose even
        though row 0 — the row the clip gather aliases it onto — is
        fully authoritative."""
        scores = np.array([[1.0, 99.0]], np.float32)
        gids = np.array([[0, -1]])
        authority = np.array([True])          # row 0 authoritative
        s, g = merge_topk_candidates(scores, gids, authority, k=2)
        assert g.tolist() == [[0, -1]]
        assert s[0, 0] == 1.0 and np.isneginf(s[0, 1])

    def test_2d_mask_true_column_cannot_validate_padding(self):
        """2-D per-candidate authority (planner ownership bits): an
        all-True mask column over a padding slot must still be
        rejected by the pre-applied (gids >= 0) term."""
        scores = np.array([[2.0, 5.0], [3.0, 4.0]], np.float32)
        gids = np.array([[7, -1], [-1, 8]])
        authority = np.ones((2, 2), bool)     # caller masks nothing
        s, g = merge_topk_candidates(scores, gids, authority, k=2)
        assert g.tolist() == [[7, -1], [8, -1]]
        assert np.isneginf(s[0, 1]) and np.isneginf(s[1, 1])

    def test_2d_mask_filters_real_candidates(self):
        """The 2-D mask still does its real job on non-padding rows."""
        scores = np.array([[5.0, 4.0, 3.0]], np.float32)
        gids = np.array([[10, 11, 12]])
        authority = np.array([[False, True, True]])
        s, g = merge_topk_candidates(scores, gids, authority, k=2)
        assert g.tolist() == [[11, 12]]

    def test_all_padding_row_yields_empty(self):
        scores = np.full((1, 3), 9.0, np.float32)
        gids = np.full((1, 3), -1)
        s, g = merge_topk_candidates(scores, gids,
                                     np.ones((1, 3), bool), k=4)
        assert (g == -1).all() and np.isneginf(s).all()


# ----------------------------------------------------------------------
# registry semantics
# ----------------------------------------------------------------------
class TestTenantRegistry:
    def test_resolve_persists_across_reopen(self, tmp_path):
        reg = TenantRegistry(str(tmp_path))
        a, b = reg.resolve("acme"), reg.resolve("globex")
        assert reg.resolve("acme") == a            # stable
        reg2 = TenantRegistry(str(tmp_path))
        assert reg2.lookup("acme") == a
        assert reg2.lookup("globex") == b
        assert reg2.name_of(a) == "acme"

    def test_default_tenant_is_zero(self, tmp_path):
        reg = TenantRegistry(str(tmp_path))
        assert reg.resolve("") == 0
        assert reg.name_of(0) == ""
        assert reg.name_of(12345) == ""            # unknown id tolerated

    def test_unknown_visibility_fails_closed(self, tmp_path):
        reg = TenantRegistry(str(tmp_path))
        reg.resolve("acme")
        tids = reg.visible_tids(("ghost",))
        assert tids is not None and len(tids) == 0
        mask = visible_rows(np.zeros(5, np.int32), tids)
        assert not mask.any()                      # every row masked

    def test_visible_rows_semantics(self):
        rows = np.array([0, 1, 2, 1, 0], np.int32)
        assert visible_rows(rows, None) is None    # unscoped
        one = visible_rows(rows, np.array([1], np.int32))
        assert one.tolist() == [False, True, False, True, False]
        two = visible_rows(rows, np.array([0, 2], np.int32))
        assert two.tolist() == [True, False, True, False, True]

    def test_visibility_key_canonical(self):
        assert visibility_key(None) == ()
        assert visibility_key("acme") == ("acme",)
        assert visibility_key(["b", "a", "b"]) == ("a", "b")


# ----------------------------------------------------------------------
# seeded multi-tenant leakage fuzz
# ----------------------------------------------------------------------
def _mk_store(root, quantized):
    store = LiveVectorLake(str(root), dim=DIM, hot_capacity=24,
                           cold_checkpoint_interval=2,
                           quantized=quantized)
    # small segments + IVF segments both appear at these sizes
    store.hot.index.ivf_min_rows = 16
    return store


def _fuzz_ingest(store, rng, n_ops=30):
    """Seeded interleaved multi-tenant ingest (doc space wide enough
    that live rows overflow the memtable and force inline IVF seals).
    Returns (doc -> tenant ownership map, commit timestamps)."""
    owner, stamps, ts = {}, [], 2_000_000
    for i in range(n_ops):
        tenant = TENANTS[int(rng.integers(0, len(TENANTS)))]
        doc = f"{tenant or 'pub'}-d{int(rng.integers(0, 8))}"
        owner[doc] = tenant
        word = f"tok{int(rng.integers(0, 40))}"
        text = (f"{doc} revision {i} about {word}.\n\n"
                f"second paragraph of {doc} mentions {word} again.")
        store.ingest(doc, text, ts=ts, tenant=tenant)
        stamps.append(ts)
        ts += 1 + int(rng.integers(1, 60))
        if i == n_ops // 3:
            # publish a SMALL (< ivf_min_rows) segment so the fused
            # block carries segment rows, not just the memtable
            store.hot.index.seal_if_above(0.0)
    store.cold.compact()                   # archives carry tenant_ids
    return owner, stamps


def _scopes(rng):
    singles = [(t,) for t in TENANTS]
    pair = tuple(sorted(rng.choice(
        [t for t in TENANTS if t], 2, replace=False)))
    return singles + [pair]


def _assert_scoped(rows_of_lists, scope, owner, ctx):
    allowed = set(scope)
    for row in rows_of_lists:
        for r in row:
            assert owner[r.doc_id] in allowed, (ctx, r.doc_id, r.tenant)
            assert r.tenant == owner[r.doc_id], (ctx, r.doc_id, r.tenant)


def _check_store(store, owner, stamps, rng, ctx=""):
    """Zero foreign-tenant rows on every path x every scope, plus
    fail-closed unknown scope and all-visible == unscoped."""
    texts = [f"revision about tok{int(rng.integers(0, 40))}"
             for _ in range(3)]
    instants = sorted({stamps[0] - 1, stamps[len(stamps) // 2],
                       stamps[-1], stamps[-1] + 10})
    windows = [(stamps[0], stamps[-1] + 1),
               (stamps[len(stamps) // 3], stamps[-1])]
    oracle = TemporalEngine(store.cold, fused=False,
                            quantized=store.quantized)
    oracle.tenant_namer = store.tenants.name_of
    qvecs = store.embedder.embed(texts)
    for scope in _scopes(rng):
        vis = scope[0] if len(scope) == 1 else scope
        tids = store.tenants.visible_tids(vis)
        cur = store.query_batch(texts, k=8, visibility=vis)
        _assert_scoped(cur, scope, owner, (ctx, "current", scope))
        for ts in instants:
            at = store.query_batch(texts, k=8, at=ts, visibility=vis)
            _assert_scoped(at, scope, owner, (ctx, "at", ts, scope))
            orc = oracle.query_at_batch(qvecs, ts, k=8, visible=tids)
            _assert_scoped(orc, scope, owner, (ctx, "oracle", ts, scope))
        for t0, t1 in windows:
            win = store.query_batch(texts, k=8, window=(t0, t1),
                                    visibility=vis)
            _assert_scoped(win, scope, owner, (ctx, "window", scope))
            orc = oracle.query_window_batch(qvecs, t0, t1, k=8,
                                            visible=tids)
            _assert_scoped(orc, scope, owner, (ctx, "oracle-win", scope))
    # unknown tenant: fail closed, not error
    for res in (store.query_batch(texts, k=8, visibility="ghost"),
                store.query_batch(texts, k=8, at=instants[1],
                                  visibility="ghost"),
                store.query_batch(texts, k=8,
                                  window=windows[0],
                                  visibility="ghost")):
        assert all(len(row) == 0 for row in res), (ctx, "ghost scope")
    # an all-tenants scope is byte-identical to unscoped
    for kw in ({}, {"at": instants[1]}, {"window": windows[0]}):
        base = store.query_batch(texts, k=8, **kw)
        full = store.query_batch(texts, k=8, visibility=tuple(TENANTS),
                                 **kw)
        for a, b in zip(base, full):
            assert [r.chunk_id for r in a] == [r.chunk_id for r in b]
            assert [r.score for r in a] == [r.score for r in b]


class TestCrossTenantLeakageFuzz:
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("seed", range(3))
    def test_no_leakage_all_paths(self, tmp_path, seed, quantized):
        rng = np.random.default_rng(seed)
        store = _mk_store(tmp_path, quantized)
        owner, stamps = _fuzz_ingest(store, rng)
        # both segment kinds present: fused-small and IVF
        segs = store.hot.index.segments.values()
        assert any(s.ivf is not None for s in segs)
        _check_store(store, owner, stamps, rng, ctx=f"live q8={quantized}")

    @pytest.mark.parametrize("quantized", [False, True])
    def test_no_leakage_after_reopen(self, tmp_path, quantized):
        rng = np.random.default_rng(7)
        store = _mk_store(tmp_path, quantized)
        owner, stamps = _fuzz_ingest(store, rng)
        del store
        # reopen adopts the persisted quantized flag; the DEFAULT
        # ivf_min_rows (1024) demotes the data-scaled IVF segments,
        # which on the quantized path makes them SOLO scan sources —
        # visibility must hold there too
        store2 = LiveVectorLake(str(tmp_path), dim=DIM,
                                cold_checkpoint_interval=2)
        assert store2.quantized == quantized
        if quantized:
            assert store2.hot.index._catalog().solo
        _check_store(store2, owner, stamps, rng,
                     ctx=f"reopen q8={quantized}")


class TestSingleTenantIdentical:
    @pytest.mark.parametrize("quantized", [False, True])
    def test_scoped_equals_unscoped_on_single_tenant_corpus(
            self, tmp_path, quantized):
        store = _mk_store(tmp_path, quantized)
        ts = 3_000_000
        for i in range(10):
            store.ingest(f"s-d{i % 4}",
                         f"solo doc {i} alpha beta tok{i}.\n\n"
                         f"gamma delta paragraph {i}.",
                         ts=ts + i * 100, tenant="solo")
        texts = ["alpha beta", "gamma delta", "tok3"]
        for kw in ({}, {"at": ts + 450},
                   {"window": (ts, ts + 1000)}):
            a = store.query_batch(texts, k=6, **kw)
            b = store.query_batch(texts, k=6, visibility="solo", **kw)
            for x, y in zip(a, b):
                assert [r.chunk_id for r in x] == [r.chunk_id for r in y]
                assert [r.score for r in x] == [r.score for r in y]
                assert all(r.tenant == "solo" for r in y)


# ----------------------------------------------------------------------
# serving gates: per-tenant quota/rate, bucketing, trace attrs, and the
# write-side admission path
# ----------------------------------------------------------------------
class TestTenantServingGates:
    def test_tenant_quota_caps_queue_share(self):
        b = Batcher(run_batch=lambda ps: ps, tenant_quota=2)
        r1 = b.submit("a1", tenant="acme")
        r2 = b.submit("a2", tenant="acme")
        r3 = b.submit("a3", tenant="acme")       # over quota
        other = b.submit("g1", tenant="globex")  # own slice, unaffected
        assert r3.done and isinstance(r3.error, AdmissionRejected)
        assert "quota" in str(r3.error) and "acme" in str(r3.error)
        assert not r1.done and not r2.done and not other.done
        b.drain()
        assert r1.result == "a1" and r2.result == "a2"
        # slots released on dispatch: acme admits again
        r4 = b.submit("a4", tenant="acme")
        assert not r4.done

    def test_tenant_rate_token_bucket(self):
        # refill is negligible at 1/1000s, so burst=2 admits exactly 2
        b = Batcher(run_batch=lambda ps: ps, tenant_rate=0.001,
                    tenant_burst=2)
        r1 = b.submit("x1", tenant="acme")
        r2 = b.submit("x2", tenant="acme")
        r3 = b.submit("x3", tenant="acme")
        fresh = b.submit("y1", tenant="globex")  # its own bucket
        assert not r1.done and not r2.done and not fresh.done
        assert r3.done and isinstance(r3.error, AdmissionRejected)
        assert "rate" in str(r3.error)

    def test_rejections_counted_per_tenant(self):
        from repro.obs import REGISTRY
        b = Batcher(run_batch=lambda ps: ps, tenant_quota=1)
        b.submit("p", tenant="acme")
        b.submit("q", tenant="acme")
        c = REGISTRY.counter("batcher_tenant_rejected",
                             batcher=b.label, tenant="acme")
        assert int(c.value) == 1

    def test_visibility_scopes_batch_separately(self):
        calls = []

        def fake_query_batch(texts, k=5, at=None, window=None,
                             visibility=None):
            calls.append((tuple(texts), visibility))
            return [[] for _ in texts]

        b = intent_batcher(fake_query_batch, k=3)
        b.submit(("q one", None, None, "acme"))
        b.submit(("q two", None, None, "acme"))
        b.submit(("q three", None, None, "globex"))
        b.drain()
        assert sorted(c[1] for c in calls) == ["acme", "globex"]
        by_vis = {c[1]: c[0] for c in calls}
        assert by_vis["acme"] == ("q one", "q two")

    def test_trace_carries_tenant_attr(self):
        from repro.obs.trace import current_trace, trace
        with trace("batch", intent="current", tenant="acme"):
            tr = current_trace()
            assert tr.attrs == {"tenant": "acme"}
        assert tr.to_dict()["attrs"] == {"tenant": "acme"}
        assert "tenant=acme" in tr.render()

    def test_ingest_admission_bounded_and_counted(self, tmp_path):
        from repro.obs import REGISTRY
        store = LiveVectorLake(str(tmp_path), dim=DIM,
                               max_pending_ingest=2)
        store.ingest("d0", "warm doc.", ts=1_000)  # single caller admits
        base = int(REGISTRY.counter("ingest_rejected").value)
        errs, done = [], []

        def worker(i):
            try:
                store.ingest(f"w{i}", f"worker doc {i}.", ts=2_000 + i)
                done.append(i)
            except AdmissionRejected as e:
                errs.append(e)

        with store._write_lock:                  # stall the single writer
            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(2)]
            for t in threads:
                t.start()
            deadline = time.time() + 5.0
            while store._ingest_pending < 2 and time.time() < deadline:
                time.sleep(0.01)
            assert store._ingest_pending == 2    # both convoyed, admitted
            with pytest.raises(AdmissionRejected):
                store.ingest("w9", "over the bound.", ts=9_000)
        for t in threads:
            t.join()
        assert sorted(done) == [0, 1] and not errs
        assert int(REGISTRY.counter("ingest_rejected").value) == base + 1
        assert store._ingest_pending == 0
