"""Focused unit tests: hot-tier slot mechanics, WAL state machine,
cold-tier snapshot isolation, embedding cache."""
import numpy as np
import pytest

from repro.core.cold_tier import ColdTier
from repro.core.embedder import CachingEmbedder, HashProjectionEmbedder
from repro.core.hot_tier import HotTier
from repro.core.types import ChunkRecord, VALID_TO_OPEN
from repro.core.wal import (ABORT, COLD_OK, COMMIT, HOT_OK, INTENT,
                            WriteAheadLog)


def _rec(doc, pos, text, ts=1000, dim=8, seed=0):
    rng = np.random.default_rng(seed + pos)
    e = rng.standard_normal(dim).astype(np.float32)
    e /= np.linalg.norm(e)
    return ChunkRecord(chunk_id=f"h{doc}{pos}", doc_id=doc, position=pos,
                       valid_from=ts, text=text, embedding=e)


class TestHotTier:
    def test_grow_beyond_capacity(self):
        ht = HotTier(dim=8, capacity=4)
        ht.insert([_rec("d", i, f"t{i}") for i in range(10)])
        assert len(ht) == 10 and ht.capacity >= 10

    def test_replace_same_key_reuses_slot(self):
        ht = HotTier(dim=8, capacity=8)
        ht.insert([_rec("d", 0, "old")])
        ht.insert([_rec("d", 0, "new", seed=9)])
        assert len(ht) == 1
        res = ht.search(ht._emb[ht._by_key[("d", 0)]], k=1)[0]
        assert res[0].text == "new"

    def test_delete_frees_and_masks(self):
        ht = HotTier(dim=8, capacity=8)
        ht.insert([_rec("d", i, f"t{i}") for i in range(3)])
        q = ht._emb[ht._by_key[("d", 1)]].copy()
        ht.delete([("d", 1)])
        assert len(ht) == 2
        for r in ht.search(q, k=3)[0]:
            assert r.position != 1               # deleted never returned

    def test_search_empty(self):
        ht = HotTier(dim=8)
        assert ht.search(np.ones(8, np.float32), k=3) == [[]]

    def test_clear(self):
        ht = HotTier(dim=8, capacity=4)
        ht.insert([_rec("d", 0, "x")])
        ht.clear()
        assert len(ht) == 0 and ht.capacity == 4


class TestWALStateMachine:
    def test_happy_path(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        t = wal.begin("ingest", {"doc": "d"})
        for s in (COLD_OK, HOT_OK, COMMIT):
            wal.mark(t, s)
        assert wal.pending() == []

    def test_no_backwards_transition(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        t = wal.begin("ingest")
        wal.mark(t, HOT_OK)
        with pytest.raises(ValueError):
            wal.mark(t, COLD_OK)

    def test_unknown_txn(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        with pytest.raises(KeyError):
            wal.mark(99, COMMIT)

    def test_restart_recovers_states(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        wal = WriteAheadLog(p)
        t1 = wal.begin("a")
        t2 = wal.begin("b", {"k": 1})
        wal.mark(t1, COLD_OK)
        wal.mark(t2, COMMIT)
        wal2 = WriteAheadLog(p)
        assert wal2.state(t1) == COLD_OK and wal2.state(t2) == COMMIT
        assert [t for t, _, _ in wal2.pending()] == [t1]
        assert wal2.payload(t2) == {"k": 1}
        t3 = wal2.begin("c")
        assert t3 > t2                            # ids keep increasing

    def test_compaction_keeps_pending(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        wal = WriteAheadLog(p)
        t1 = wal.begin("a")
        wal.mark(t1, COMMIT)
        t2 = wal.begin("b")
        wal.truncate_committed()
        wal3 = WriteAheadLog(p)
        assert wal3.state(t1) is None
        assert wal3.state(t2) == INTENT


class TestColdTierIsolation:
    def test_uncommitted_invisible(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "visible", ts=100)], [], ts=100)
        ct.commit([_rec("d", 1, "hidden", ts=200)], [], ts=200,
                  uncommitted=True)
        snap = ct.snapshot()
        assert snap.texts == ["visible"]
        ct.mark_committed(2)
        assert len(ct.snapshot()) == 2

    def test_snapshot_at_version(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "v1", ts=100)], [], ts=100)
        ct.commit([_rec("d", 0, "v2", ts=200)],
                  [{"doc_id": "d", "position": 0, "closed_at": 200,
                    "status": "superseded"}], ts=200)
        s1 = ct.snapshot(version=1)
        assert s1.texts == ["v1"]
        s2 = ct.snapshot(version=2)
        assert s2.texts == ["v2"]

    def test_corrupt_segment_detected(self, tmp_path):
        import os
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "x", ts=100)], [], ts=100)
        seg_dir = os.path.join(str(tmp_path), "segments")
        seg = os.path.join(seg_dir, os.listdir(seg_dir)[0])
        with open(seg, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))     # guaranteed bit flip
        with pytest.raises(IOError, match="checksum"):
            ct.snapshot()


class TestEmbeddingCache:
    def test_dedup_across_calls(self):
        ce = CachingEmbedder(HashProjectionEmbedder(dim=16))
        a = ce.embed_chunks(["h1", "h2"], ["text one", "text two"])
        b = ce.embed_chunks(["h1", "h3"], ["text one", "text three"])
        assert ce.hits == 1 and ce.misses == 3
        np.testing.assert_array_equal(a[0], b[0])

    def test_warm_preseeds(self):
        ce = CachingEmbedder(HashProjectionEmbedder(dim=16))
        ce.warm(["hx"], np.ones((1, 16), np.float32))
        out = ce.embed_chunks(["hx"], ["whatever"])
        assert ce.hits == 1 and ce.misses == 0
        np.testing.assert_array_equal(out[0], np.ones(16, np.float32))


class TestRAGEngine:
    def test_end_to_end_generation(self, tmp_path):
        from repro.core.store import LiveVectorLake
        from repro.models.transformer import TransformerConfig
        from repro.serve.engine import RAGEngine
        store = LiveVectorLake(str(tmp_path / "s"), dim=64)
        store.ingest("d", "The API limit is 500 requests.", ts=1000)
        store.ingest("d", "The API limit is 900 requests.", ts=2000)
        cfg = TransformerConfig(name="t", vocab=512, d_model=32,
                                n_layers=1, n_heads=2, n_kv=2, d_head=16,
                                d_ff=64, act="swiglu", remat=False)
        eng = RAGEngine(store, cfg, max_prompt=64)
        now = eng.answer("API limit", k=1, max_new_tokens=3)
        old = eng.answer("API limit", k=1, at=1500, max_new_tokens=3)
        assert "900" in now.retrieved[0].text
        assert "500" in old.retrieved[0].text
        assert len(now.token_ids) == 3
