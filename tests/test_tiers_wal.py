"""Focused unit tests: hot-tier slot mechanics, WAL state machine,
cold-tier snapshot isolation (incl. checkpoint/compaction crash
injection), embedding cache."""
import os

import numpy as np
import pytest

from repro.core.cold_tier import ColdTier, FaultPoint
from repro.core.embedder import CachingEmbedder, HashProjectionEmbedder
from repro.core.hot_tier import HotTier
from repro.core.types import ChunkRecord, VALID_TO_OPEN
from repro.core.wal import (COLD_OK, COMMIT, HOT_OK, INTENT,
                            WriteAheadLog)


def _rec(doc, pos, text, ts=1000, dim=8, seed=0):
    rng = np.random.default_rng(seed + pos)
    e = rng.standard_normal(dim).astype(np.float32)
    e /= np.linalg.norm(e)
    return ChunkRecord(chunk_id=f"h{doc}{pos}", doc_id=doc, position=pos,
                       valid_from=ts, text=text, embedding=e)


class TestHotTier:
    def test_grow_beyond_capacity(self):
        ht = HotTier(dim=8, capacity=4)
        ht.insert([_rec("d", i, f"t{i}") for i in range(10)])
        assert len(ht) == 10 and ht.capacity >= 10

    def test_replace_same_key_reuses_slot(self):
        ht = HotTier(dim=8, capacity=8)
        ht.insert([_rec("d", 0, "old")])
        ht.insert([_rec("d", 0, "new", seed=9)])
        assert len(ht) == 1
        res = ht.search(ht._emb[ht._by_key[("d", 0)]], k=1)[0]
        assert res[0].text == "new"

    def test_delete_frees_and_masks(self):
        ht = HotTier(dim=8, capacity=8)
        ht.insert([_rec("d", i, f"t{i}") for i in range(3)])
        q = ht._emb[ht._by_key[("d", 1)]].copy()
        ht.delete([("d", 1)])
        assert len(ht) == 2
        for r in ht.search(q, k=3)[0]:
            assert r.position != 1               # deleted never returned

    def test_search_empty(self):
        ht = HotTier(dim=8)
        assert ht.search(np.ones(8, np.float32), k=3) == [[]]

    def test_clear(self):
        ht = HotTier(dim=8, capacity=4)
        ht.insert([_rec("d", 0, "x")])
        ht.clear()
        assert len(ht) == 0 and ht.capacity == 4


class TestWALStateMachine:
    def test_happy_path(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        t = wal.begin("ingest", {"doc": "d"})
        for s in (COLD_OK, HOT_OK, COMMIT):
            wal.mark(t, s)
        assert wal.pending() == []

    def test_no_backwards_transition(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        t = wal.begin("ingest")
        wal.mark(t, HOT_OK)
        with pytest.raises(ValueError):
            wal.mark(t, COLD_OK)

    def test_unknown_txn(self, tmp_path):
        wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
        with pytest.raises(KeyError):
            wal.mark(99, COMMIT)

    def test_restart_recovers_states(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        wal = WriteAheadLog(p)
        t1 = wal.begin("a")
        t2 = wal.begin("b", {"k": 1})
        wal.mark(t1, COLD_OK)
        wal.mark(t2, COMMIT)
        wal2 = WriteAheadLog(p)
        assert wal2.state(t1) == COLD_OK and wal2.state(t2) == COMMIT
        assert [t for t, _, _ in wal2.pending()] == [t1]
        assert wal2.payload(t2) == {"k": 1}
        t3 = wal2.begin("c")
        assert t3 > t2                            # ids keep increasing

    def test_compaction_keeps_pending(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        wal = WriteAheadLog(p)
        t1 = wal.begin("a")
        wal.mark(t1, COMMIT)
        t2 = wal.begin("b")
        wal.truncate_committed()
        wal3 = WriteAheadLog(p)
        assert wal3.state(t1) is None
        assert wal3.state(t2) == INTENT


class TestColdTierIsolation:
    def test_uncommitted_invisible(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "visible", ts=100)], [], ts=100)
        ct.commit([_rec("d", 1, "hidden", ts=200)], [], ts=200,
                  uncommitted=True)
        snap = ct.snapshot()
        assert snap.texts == ["visible"]
        ct.mark_committed(2)
        assert len(ct.snapshot()) == 2

    def test_snapshot_at_version(self, tmp_path):
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "v1", ts=100)], [], ts=100)
        ct.commit([_rec("d", 0, "v2", ts=200)],
                  [{"doc_id": "d", "position": 0, "closed_at": 200,
                    "status": "superseded"}], ts=200)
        s1 = ct.snapshot(version=1)
        assert s1.texts == ["v1"]
        s2 = ct.snapshot(version=2)
        assert s2.texts == ["v2"]

    def test_corrupt_segment_detected(self, tmp_path):
        import os
        ct = ColdTier(str(tmp_path), dim=8)
        ct.commit([_rec("d", 0, "x", ts=100)], [], ts=100)
        ct.commit([_rec("e", 0, "y", ts=200)], [], ts=200)
        seg_dir = os.path.join(str(tmp_path), "segments")
        seg_name = sorted(os.listdir(seg_dir))[0]
        seg = os.path.join(seg_dir, seg_name)
        with open(seg, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))     # guaranteed bit flip
        # the direct load raises the TYPED error (subclass of IOError,
        # so pre-§16 broad handlers still catch it)
        with pytest.raises(IOError, match="checksum"):
            ct.load_segment(seg_name, ct.read_entries(1, 1)[0]["checksum"])
        # containment (DESIGN.md §16): the fold quarantines the rotten
        # segment and KEEPS SERVING the surviving rows instead of
        # killing the store
        snap = ct.snapshot()
        assert snap.texts == ["y"]
        assert ct.quarantine.is_quarantined(seg_name)
        assert not os.path.exists(seg)
        assert any(r["data_loss"] and r["docs"] == ["d"]
                   for r in ct.quarantine.records())


def _close(doc, pos, ts):
    return {"doc_id": doc, "position": pos, "closed_at": ts,
            "status": "superseded"}


class TestColdTierCrashRecovery:
    """ISSUE 3 satellite: kill between segment write, log append, and
    checkpoint write — and mid-compaction. Recovery (a fresh ColdTier on
    the same root) must never surface an uncommitted checkpoint, lose a
    closure, or diverge from the from-scratch fold."""

    def _seed(self, root, n=7, interval=4):
        ct = ColdTier(root, dim=8, checkpoint_interval=interval)
        ts = 1000
        for v in range(n):
            closures = [] if v == 0 else [_close("d", 0, ts)]
            ct.commit([_rec("d", 0, f"t{v}", ts=ts)], closures, ts)
            ts += 100
        return ct, ts

    def _assert_consistent(self, root, tag=""):
        ct = ColdTier(root, dim=8)           # fresh open = recovery path
        a = ct.snapshot(include_closed=True)
        b = ct.snapshot(include_closed=True, from_scratch=True)
        assert a.chunk_ids == b.chunk_ids, tag
        np.testing.assert_array_equal(a.valid_to, b.valid_to, err_msg=tag)
        return ct

    def test_crash_between_segment_and_log(self, tmp_path):
        root = str(tmp_path)
        ct, ts = self._seed(root)
        with pytest.raises(FaultPoint):
            ct.commit([_rec("d", 0, "lost", ts=ts)], [_close("d", 0, ts)],
                      ts, fail_after="segment")
        ct2 = self._assert_consistent(root, "segment crash")
        # the orphaned segment's commit never became visible, and the
        # in-flight closure was NOT applied (atomic commit)
        snap = ct2.snapshot()
        assert snap.texts == ["t6"]          # pre-crash head still open
        # the version number is reused by the next commit
        v = ct2.commit([_rec("d", 0, "retry", ts=ts + 1)],
                       [_close("d", 0, ts + 1)], ts + 1)
        assert v == 8
        assert ct2.snapshot().texts == ["retry"]

    def test_crash_between_log_and_checkpoint(self, tmp_path):
        root = str(tmp_path)
        ct, ts = self._seed(root, n=7, interval=4)  # next commit = v8 = ckpt
        with pytest.raises(FaultPoint):
            ct.commit([_rec("d", 0, "v8", ts=ts)], [_close("d", 0, ts)],
                      ts, fail_after="log")
        ct2 = self._assert_consistent(root, "log crash")
        # the commit IS durable (log entry landed); only the checkpoint
        # is missing — no closure lost
        assert ct2.latest_version() == 8
        assert ct2.snapshot().texts == ["v8"]
        assert [m["version"] for m in ct2.checkpoints()] == [4]

    def test_crash_between_checkpoint_npz_and_meta(self, tmp_path):
        root = str(tmp_path)
        ct, ts = self._seed(root, n=7, interval=4)
        with pytest.raises(FaultPoint):
            ct.commit([_rec("d", 0, "v8", ts=ts)], [_close("d", 0, ts)],
                      ts, fail_after="checkpoint_data")
        # npz written, meta missing: the checkpoint is NOT durable
        ckpt_dir = os.path.join(root, "_ckpt")
        assert any(f.endswith(".npz") and f.startswith("ckpt-00000008")
                   for f in os.listdir(ckpt_dir))
        ct2 = self._assert_consistent(root, "checkpoint crash")
        assert [m["version"] for m in ct2.checkpoints()] == [4]
        # recovery swept the orphan npz
        assert not any(f.startswith("ckpt-00000008")
                       for f in os.listdir(ckpt_dir))
        # and the next checkpoint write succeeds normally
        ct2.write_checkpoint()
        assert [m["version"] for m in ct2.checkpoints()] == [4, 8]

    def test_crash_between_archive_and_manifest(self, tmp_path):
        root = str(tmp_path)
        ct, ts = self._seed(root, n=10, interval=0)
        with pytest.raises(FaultPoint):
            ct.compact(fail_after="archive")
        arc_dir = os.path.join(root, "_archive")
        assert any(f.endswith(".npz") for f in os.listdir(arc_dir))
        ct2 = self._assert_consistent(root, "compact crash")
        # manifest never landed: no archive is visible, orphan swept
        assert ct2.archives() == []
        assert not any(f.endswith(".npz") for f in os.listdir(arc_dir))
        # re-running compaction completes
        r = ct2.compact()
        assert r["archived_runs"] == 1
        self._assert_consistent(root, "after recompact")

    def test_uncommitted_checkpoint_never_surfaced(self, tmp_path):
        """A checkpoint that baked a version later compensated by WAL
        reconciliation must not serve stale rows."""
        root = str(tmp_path)
        ct, ts = self._seed(root, n=7, interval=4)
        ct.commit([_rec("d", 0, "maybe", ts=ts)], [_close("d", 0, ts)], ts)
        assert [m["version"] for m in ct.checkpoints()] == [4, 8]
        ct.mark_committed(8, committed=False)   # compensate v8
        assert [m["version"] for m in ct.checkpoints()] == [4]
        ct2 = self._assert_consistent(root, "compensated")
        snap = ct2.snapshot()
        assert snap.texts == ["t6"]          # v8 row invisible
        # closure applied by v8 is also rolled back: t6 is open again
        assert snap.valid_to.tolist() == [VALID_TO_OPEN]

    def test_closures_survive_crash_loop(self, tmp_path):
        """Repeated crash/reopen cycles at every fault point: the final
        store state always matches the from-scratch fold and no closure
        is lost."""
        root = str(tmp_path)
        ct = ColdTier(root, dim=8, checkpoint_interval=2)
        ts = 1000
        for v, fault in enumerate([None, "segment", None, "log", None,
                                   "checkpoint_data", None, None]):
            closures = [] if v == 0 else [_close("d", 0, ts)]
            try:
                ct.commit([_rec("d", 0, f"t{v}", ts=ts)], closures, ts,
                          fail_after=fault)
            except FaultPoint:
                pass
            ct = ColdTier(root, dim=8, checkpoint_interval=2)  # reopen
            ts += 100
        snap = ct.snapshot(include_closed=True)
        ref = ct.snapshot(include_closed=True, from_scratch=True)
        assert snap.chunk_ids == ref.chunk_ids
        np.testing.assert_array_equal(snap.valid_to, ref.valid_to)
        # exactly one open row at the head, every superseded row closed
        open_rows = [i for i, vt in enumerate(snap.valid_to)
                     if vt == VALID_TO_OPEN]
        assert len(open_rows) == 1


class TestEmbeddingCache:
    def test_dedup_across_calls(self):
        ce = CachingEmbedder(HashProjectionEmbedder(dim=16))
        a = ce.embed_chunks(["h1", "h2"], ["text one", "text two"])
        b = ce.embed_chunks(["h1", "h3"], ["text one", "text three"])
        assert ce.hits == 1 and ce.misses == 3
        np.testing.assert_array_equal(a[0], b[0])

    def test_warm_preseeds(self):
        ce = CachingEmbedder(HashProjectionEmbedder(dim=16))
        ce.warm(["hx"], np.ones((1, 16), np.float32))
        out = ce.embed_chunks(["hx"], ["whatever"])
        assert ce.hits == 1 and ce.misses == 0
        np.testing.assert_array_equal(out[0], np.ones(16, np.float32))


class TestRAGEngine:
    def test_end_to_end_generation(self, tmp_path):
        from repro.core.store import LiveVectorLake
        from repro.models.transformer import TransformerConfig
        from repro.serve.engine import RAGEngine
        store = LiveVectorLake(str(tmp_path / "s"), dim=64)
        store.ingest("d", "The API limit is 500 requests.", ts=1000)
        store.ingest("d", "The API limit is 900 requests.", ts=2000)
        cfg = TransformerConfig(name="t", vocab=512, d_model=32,
                                n_layers=1, n_heads=2, n_kv=2, d_head=16,
                                d_ff=64, act="swiglu", remat=False)
        eng = RAGEngine(store, cfg, max_prompt=64)
        now = eng.answer("API limit", k=1, max_new_tokens=3)
        old = eng.answer("API limit", k=1, at=1500, max_new_tokens=3)
        assert "900" in now.retrieved[0].text
        assert "500" in old.retrieved[0].text
        assert len(now.token_ids) == 3
