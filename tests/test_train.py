"""Training substrate tests: optimizers converge, checkpoint round-trip +
crash recovery + elastic restore, gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import grad_compress
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adafactor, adamw, get_optimizer
from repro.train.train_loop import Trainer, make_train_step


def _quadratic_problem(seed=0, dim=8):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.standard_normal((dim, dim)).astype(np.float32))

    def loss_fn(params, batch):
        return jnp.mean((params["w"] - target) ** 2) + \
            jnp.mean((params["b"] - 1.0) ** 2)

    params = {"w": jnp.zeros((dim, dim)), "b": jnp.zeros((dim,))}
    return loss_fn, params, target


@pytest.mark.parametrize("opt_name,lr", [("adamw", 0.05),
                                         ("adafactor", 0.5),
                                         ("sgd", 0.5)])
def test_optimizer_converges(opt_name, lr):
    loss_fn, params, _ = _quadratic_problem()
    opt = get_optimizer(opt_name, lr=lr, warmup_steps=1) \
        if opt_name != "sgd" else get_optimizer(opt_name, lr=lr)
    step = jax.jit(make_train_step(loss_fn, opt))
    opt_state = opt.init(params)
    l0 = float(loss_fn(params, None))
    for i in range(60):
        params, opt_state, _, m = step(params, opt_state, None, None,
                                       jnp.asarray(i))
    assert float(m["loss"]) < 0.1 * l0


def test_adamw_weight_decay_shrinks():
    opt = adamw(lr=0.1, weight_decay=0.5, warmup_steps=1)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros((4,))}
    p, _ = opt.update(zero_g, state, params, jnp.asarray(0))
    assert float(p["w"][0]) < 1.0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = opt.init(params)
    assert state["w"]["r"].shape == (64,)
    assert state["w"]["c"].shape == (32,)
    assert state["b"]["v"].shape == (32,)
    # memory: factored state is O(m+n), not O(mn)
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(state))
    n_param = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert n_state < 0.1 * n_param


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "nested": {"b": jnp.ones((5,), jnp.int32)}}
        mgr.save(7, tree, extra={"note": "x"})
        restored, step, extra = mgr.restore(tree)
        assert step == 7 and extra == {"note": "x"}
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.ones((4,))}
        d = mgr.save(1, tree)
        leaf = os.path.join(d, "leaf_00000.npy")
        with open(leaf, "r+b") as f:
            f.seek(-1, 2)
            f.write(b"\xff")
        with pytest.raises(IOError, match="checksum"):
            mgr.restore(tree)

    def test_partial_save_invisible(self, tmp_path):
        """A save without a committed manifest must not be listed."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"a": jnp.ones(3)})
        os.makedirs(str(tmp_path / "step_0000000002.tmp"))
        assert mgr.all_steps() == [1]

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"a": jnp.ones(2)})
        assert mgr.all_steps() == [3, 4]

    def test_trainer_crash_resume(self, tmp_path):
        loss_fn, params, _ = _quadratic_problem()
        opt = adamw(lr=0.05, warmup_steps=1)
        t1 = Trainer(loss_fn, opt, params, str(tmp_path / "ck"),
                     checkpoint_every=5, async_checkpoint=False)
        t1.run([None] * 10, n_steps=10)
        # simulated crash: brand-new trainer, same dir
        t2 = Trainer(loss_fn, opt, params, str(tmp_path / "ck"),
                     checkpoint_every=5, async_checkpoint=False)
        assert t2.try_restore()
        assert t2.state.step == 10
        l_resumed = float(loss_fn(t2.state.params, None))
        l_fresh = float(loss_fn(params, None))
        assert l_resumed < l_fresh            # progress survived the crash

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, {"a": jnp.ones((1000, 100))}, blocking=False)
        mgr.wait()
        assert mgr.all_steps() == [3]


class TestGradCompression:
    def test_quantize_bounded_error(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
        q, scale = grad_compress.quantize_int8(x)
        err = np.abs(np.asarray(grad_compress.dequantize_int8(q, scale) - x))
        assert err.max() <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_removes_bias(self):
        """Accumulated EF-compressed grads converge to the true sum."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        ef = {"g": jnp.zeros(256)}
        acc = np.zeros(256)
        n = 200
        for _ in range(n):
            deq, ef_new = grad_compress.compress_decompress({"g": g_true},
                                                            ef)
            ef = ef_new
            acc += np.asarray(deq["g"])
        np.testing.assert_allclose(acc / n, np.asarray(g_true),
                                   rtol=0, atol=1e-2)

    def test_training_with_compression_converges(self):
        loss_fn, params, _ = _quadratic_problem()
        opt = adamw(lr=0.05, warmup_steps=1)
        t = Trainer(loss_fn, opt, params, compress_grads=True)
        hist = t.run([None] * 60, n_steps=60, log_every=60)
        assert hist[-1]["loss"] < 0.2
