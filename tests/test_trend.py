"""Bench trend gating tests (benchmarks/trend.py — ISSUE 6): metric
classification by name, loose perf ratio gates vs tight absolute
quality gates, suite-error handling, the markdown diff table, and the
CLI exit codes against the committed BENCH_PR5.json baseline."""
import copy
import json
import os

import pytest

from benchmarks.trend import classify, compare, main, render_markdown

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "BENCH_PR5.json")


def _record(rows, suite="s"):
    return {"suites": {suite: {"wall_s": 1.0, "rows": rows}}}


class TestClassify:
    @pytest.mark.parametrize("name,cls", [
        ("quantized_scan/n20000/recall_at_10", "quality-high"),
        ("change_detection/precision", "quality-high"),
        ("temporal/accuracy", "quality-high"),
        ("shard_scaling/gate", "quality-high"),
        ("temporal_scaling/gate_pass", "quality-high"),
        ("temporal/leakage_rate", "quality-low"),
        ("change_detection/false_positives", "quality-low"),
        ("quantized_scan/n20000/speedup", "perf-high"),
        ("query_throughput/store/batched_qps", "perf-high"),
        ("storage/cold_delta_savings_pct", "perf-high"),
        ("quantized_scan/bytes_n20000/reduction", "perf-high"),
        ("query_latency/current_hot_ms/p50", "perf-low"),
        ("update_perf/livevectorlake/time_to_query_s", "perf-low"),
        ("streaming_churn/max_write_stall_ms", "perf-low"),
        ("storage/hot_bytes", "perf-low"),
        ("shard_scaling/split/wall_s", "info"),
        ("temporal/n_queries", "info"),
        ("storage/hot_active_chunks", "info"),
    ])
    def test_names(self, name, cls):
        assert classify(name) == cls


class TestCompare:
    def test_identical_records_pass(self):
        rec = _record([["s/p50_ms", 10.0, ""], ["s/recall", 1.0, ""]])
        cmp = compare(rec, copy.deepcopy(rec))
        assert cmp["failures"] == []
        assert all(r["status"] == "ok" for r in cmp["rows"])

    def test_quality_drop_fails_tight(self):
        base = _record([["s/recall_at_10", 1.0, ""]])
        ok = compare(base, _record([["s/recall_at_10", 0.99, ""]]))
        assert ok["failures"] == []              # within abs 0.02
        bad = compare(base, _record([["s/recall_at_10", 0.95, ""]]))
        assert len(bad["failures"]) == 1
        assert bad["rows"][0]["status"] == "REGRESSED"

    def test_leakage_rise_fails(self):
        base = _record([["s/leakage_rate", 0.0, ""]])
        assert compare(base, _record([["s/leakage_rate", 0.5, ""]])
                       )["failures"]
        assert not compare(base, _record([["s/leakage_rate", 0.0, ""]])
                           )["failures"]

    def test_perf_gates_loosely(self):
        base = _record([["s/scan_ms", 10.0, ""]])
        # 2.2x slower: inside the default 2.5x cross-machine allowance
        assert not compare(base, _record([["s/scan_ms", 22.0, ""]])
                           )["failures"]
        # 3x slower: gated
        assert compare(base, _record([["s/scan_ms", 30.0, ""]])
                       )["failures"]
        # higher-better symmetric
        base = _record([["s/qps", 1000.0, ""]])
        assert not compare(base, _record([["s/qps", 500.0, ""]])
                           )["failures"]
        assert compare(base, _record([["s/qps", 300.0, ""]])
                       )["failures"]

    def test_sub_noise_floor_timings_are_informational(self):
        base = _record([["s/fused_ms", 0.4, ""]])
        # 3x on a 0.4ms row: below min_base, never gated
        assert not compare(base, _record([["s/fused_ms", 1.2, ""]])
                           )["failures"]
        # single-digit-ms percentile rows are below the default floor
        # too (they swing 2-6x run-to-run on identical code) — but an
        # explicit tighter floor re-arms the gate
        base = _record([["s/p99_ms", 3.8, ""]])
        new = _record([["s/p99_ms", 21.0, ""]])
        assert not compare(base, new)["failures"]
        assert compare(base, new, min_base=0.5)["failures"]

    def test_improvement_is_labeled(self):
        base = _record([["s/scan_ms", 10.0, ""]])
        cmp = compare(base, _record([["s/scan_ms", 5.0, ""]]))
        assert cmp["rows"][0]["status"] == "improved"

    def test_new_and_removed_rows_do_not_gate(self):
        base = _record([["s/a_ms", 1.0, ""]])
        new = _record([["s/b_ms", 1.0, ""]])
        cmp = compare(base, new)
        assert cmp["failures"] == []
        assert {r["status"] for r in cmp["rows"]} == {"new", "removed"}

    def test_new_suite_ok_errored_suite_fails(self):
        base = {"suites": {"a": {"wall_s": 1, "rows": [["a/x_ms", 1, ""]]}}}
        new_ok = {"suites": {
            "a": {"wall_s": 1, "rows": [["a/x_ms", 1, ""]]},
            "b": {"wall_s": 1, "rows": [["b/y_ms", 1, ""]]}}}
        assert compare(base, new_ok)["failures"] == []
        assert compare(base, new_ok)["suites"]["b"] == "new"
        new_err = {"suites": {"a": {"wall_s": 1, "error": "Boom: x"}}}
        cmp = compare(base, new_err)
        assert cmp["suites"]["a"] == "MISSING"
        assert cmp["failures"]

    def test_custom_thresholds(self):
        base = _record([["s/scan_ms", 10.0, ""]])
        new = _record([["s/scan_ms", 13.0, ""]])
        assert not compare(base, new)["failures"]
        assert compare(base, new, max_regression=0.2)["failures"]


class TestDriftCalibration:
    """Cross-record machine-drift estimation: drift is global (moves
    every wall-clock row), a real regression is local — the median
    perf-low ratio widens the perf gates, and only the outlier still
    fails."""

    def _pair(self, uniform_ratio, n=10, outlier=None):
        base = _record([[f"s/m{i}_ms", 10.0, ""] for i in range(n)])
        rows = [[f"s/m{i}_ms", 10.0 * uniform_ratio, ""]
                for i in range(n)]
        if outlier is not None:
            base["suites"]["s"]["rows"].append(["s/bad_ms", 10.0, ""])
            rows.append(["s/bad_ms", 10.0 * outlier, ""])
        return base, _record(rows)

    def test_uniformly_slower_machine_passes(self):
        # 2.8x on EVERY row would trip the raw 2.5x gate, but the
        # median ratio calibrates it away
        base, new = self._pair(2.8)
        cmp = compare(base, new)
        assert cmp["failures"] == []
        assert cmp["thresholds"]["drift"] == pytest.approx(2.8)

    def test_local_regression_still_fails_under_drift(self):
        base, new = self._pair(2.8, outlier=30.0)
        cmp = compare(base, new)
        assert len(cmp["failures"]) == 1
        assert "bad_ms" in cmp["failures"][0]

    def test_faster_machine_never_tightens(self):
        # new machine 2x FASTER: drift clamps at 1.0, so a row at the
        # edge of the raw allowance is judged exactly as without
        # calibration
        base, new = self._pair(0.5, outlier=2.4)
        cmp = compare(base, new)
        assert cmp["thresholds"]["drift"] == 1.0
        assert cmp["failures"] == []

    def test_excessive_drift_estimate_is_clamped(self):
        # >3x median is suspect (too much of the suite moved): clamp
        # to 3x, so the uniform 10x pair DOES fail
        base, new = self._pair(10.0)
        cmp = compare(base, new)
        assert cmp["thresholds"]["drift"] == 3.0
        assert cmp["failures"]

    def test_too_few_rows_no_calibration(self):
        base, new = self._pair(2.8, n=3)
        cmp = compare(base, new)
        assert cmp["thresholds"]["drift"] == 1.0
        assert len(cmp["failures"]) == 3

    def test_quality_gates_are_never_calibrated(self):
        base, new = self._pair(2.8)
        base["suites"]["s"]["rows"].append(["s/recall", 1.0, ""])
        new["suites"]["s"]["rows"].append(["s/recall", 0.9, ""])
        cmp = compare(base, new)
        assert len(cmp["failures"]) == 1
        assert "recall" in cmp["failures"][0]

    def test_drift_reported_in_markdown(self):
        base, new = self._pair(2.8)
        md = render_markdown(compare(base, new))
        assert "machine-drift calibration" in md


class TestRender:
    def test_markdown_table_shape(self):
        base = _record([["s/scan_ms", 10.0, ""], ["s/recall", 1.0, ""]])
        new = _record([["s/scan_ms", 30.0, ""], ["s/recall", 1.0, ""]])
        cmp = compare(base, new)
        md = render_markdown(cmp, "PR5", "PR6")
        assert "| suite | metric |" in md
        assert "**REGRESSED**" in md
        assert "PR5" in md and "PR6" in md
        assert "1 gated regression" in md

    def test_markdown_reports_clean_run(self):
        rec = _record([["s/scan_ms", 10.0, ""]])
        md = render_markdown(compare(rec, copy.deepcopy(rec)))
        assert "No gated regressions" in md


class TestCLI:
    def test_baseline_vs_itself_passes(self, tmp_path):
        out = tmp_path / "diff.md"
        rc = main([BASELINE, BASELINE, "--markdown", str(out)])
        assert rc == 0
        assert "No gated regressions" in out.read_text()

    def test_injected_regression_fails_the_gate(self, tmp_path):
        with open(BASELINE) as f:
            bad = json.load(f)
        for row in bad["suites"]["quantized_scan"]["rows"]:
            if row[0].endswith("recall_at_10"):
                row[1] = 0.5
        p = tmp_path / "bad.json"
        p.write_text(json.dumps(bad))
        out = tmp_path / "diff.md"
        rc = main([BASELINE, str(p), "--markdown", str(out)])
        assert rc == 1
        assert "**REGRESSED**" in out.read_text()
